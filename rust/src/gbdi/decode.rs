//! The GBDI decompression engine: format decoding, global table access,
//! and bit-exact value reconstruction (paper §IV.B).
//!
//! Two implementations share the wire format:
//!
//! * [`decompress_block`] — the scalar reference decoder, one field per
//!   read, bounds-checking the base pointer per word. Kept as the
//!   differential-testing oracle and for callers that only have a
//!   table + config in hand.
//! * [`decompress_block_lut`] — the hot-path kernel the
//!   [`GbdiCodec`](super::GbdiCodec) trait impl runs: a flat
//!   [`DecodeLut`] (built and validated once at codec construction)
//!   replaces the per-word table lookup + bounds check, the base
//!   pointer and its delta are extracted from a **single accumulator
//!   refill** (`peek`/`consume`), and RAW/REP blocks take bulk-copy
//!   paths. Output is bit-for-bit identical to the reference decoder.

use super::table::GlobalBaseTable;
use super::{BlockMode, GbdiConfig};
use crate::cluster::apply_delta;
use crate::container::Container;
use crate::util::bits::BitReader;
use crate::value::{write_word, WordSize};
use crate::{Error, Result};

/// `width[]` sentinel: this pointer is the outlier escape code.
const W_OUTLIER: u32 = u32::MAX;
/// `width[]` sentinel: this pointer names no table entry (corrupt input).
const W_INVALID: u32 = u32::MAX - 1;

/// Flat per-table decode tables: `base[]` / `width[]` indexed directly by
/// the on-wire base pointer.
///
/// Both arrays are sized `1 << ptr_bits`, so **any** pointer value the
/// wire can physically encode is in range — the per-word bounds check of
/// the reference decoder disappears. Codes past the real table (possible
/// whenever `num_bases + 1` is not a power of two) carry the `W_INVALID`
/// sentinel and surface as the same corruption error the reference
/// decoder raises; the escape code carries `W_OUTLIER`. Everything is
/// validated once in [`DecodeLut::new`], which only
/// [`GbdiCodec::try_new`](super::GbdiCodec::try_new) calls — after it has
/// checked the table/config contract (`table.len() <= num_bases`, word
/// sizes agree), so LUT construction cannot alias a real base onto the
/// escape code.
#[derive(Debug, Clone)]
pub struct DecodeLut {
    base: Box<[u64]>,
    width: Box<[u32]>,
    /// Derived per-pointer tables for the two-phase SIMD decode (W32
    /// only; all three empty when ineligible). Indexed by the on-wire
    /// pointer like `base`/`width`:
    ///
    /// * `step32[p]` — total bits the word's fused field occupies
    ///   (pointer + delta/outlier payload); **0 marks an invalid
    ///   pointer**, the rejection the reference decoder raises.
    /// * `mask32[p]` — mask extracting the payload bits that follow the
    ///   pointer (0 for exact-hit bases, `u32::MAX` for outliers).
    /// * `adj32[p]` — additive constant folding the base and the
    ///   offset-binary bias: the decoded word is
    ///   `adj32[p].wrapping_add(raw)`, which the apply kernel runs four
    ///   or eight lanes at a time.
    step32: Box<[u32]>,
    mask32: Box<[u32]>,
    adj32: Box<[u32]>,
    ptr_bits: u32,
    word_size: WordSize,
    block_bytes: usize,
    words_per_block: usize,
}

impl DecodeLut {
    /// Build the LUT for a (table, config) pair.
    ///
    /// # Panics
    ///
    /// If `table.len() > config.num_bases` (a real base would alias the
    /// outlier escape code) or the word sizes disagree — the contract
    /// [`GbdiCodec::try_new`](super::GbdiCodec::try_new) validates with a
    /// recoverable error before calling this. Enforced unconditionally:
    /// a violating LUT would decode wrong bytes as `Ok`, not fail.
    pub fn new(table: &GlobalBaseTable, config: &GbdiConfig) -> DecodeLut {
        let ptr_bits = config.base_ptr_bits();
        let size = 1usize << ptr_bits;
        assert!(
            table.len() <= config.num_bases,
            "table has {} bases, config allows {}",
            table.len(),
            config.num_bases
        );
        assert_eq!(table.word_size, config.word_size, "table/config word size mismatch");
        debug_assert!(config.outlier_code() < size as u64);
        let mut base = vec![0u64; size].into_boxed_slice();
        let mut width = vec![W_INVALID; size].into_boxed_slice();
        for (i, e) in table.entries().iter().enumerate() {
            base[i] = e.base;
            width[i] = e.width;
        }
        width[config.outlier_code() as usize] = W_OUTLIER;
        let (step32, mask32, adj32) = build_w32_tables(&base, &width, ptr_bits, config.word_size);
        DecodeLut {
            base,
            width,
            step32,
            mask32,
            adj32,
            ptr_bits,
            word_size: config.word_size,
            block_bytes: config.block_bytes,
            words_per_block: config.words_per_block(),
        }
    }
}

/// Largest `words_per_block` the two-phase SIMD decode handles (its
/// phase-1 scratch lives on the stack). Default GBDI blocks are 16
/// words; 256 covers 1 KiB W32 blocks. Larger configs fall back to the
/// reference loop.
const SIMD_MAX_WORDS: usize = 256;

/// Derive the fused `step32`/`mask32`/`adj32` tables (see [`DecodeLut`])
/// for W32 tables. Every delta width is at most 32 and `ptr_bits <= 13`,
/// so each fused field fits a single 57-bit `peek` — one refill serves
/// pointer *and* payload for every word class, including outliers.
/// Returns empty tables for W64 (wide fields can exceed the peek window).
fn build_w32_tables(
    base: &[u64],
    width: &[u32],
    ptr_bits: u32,
    word_size: WordSize,
) -> (Box<[u32]>, Box<[u32]>, Box<[u32]>) {
    let widths_fused = width
        .iter()
        .all(|&w| w <= 32 || w == W_OUTLIER || w == W_INVALID);
    if word_size != WordSize::W32 || !widths_fused || ptr_bits + 32 > 57 {
        let empty = || Vec::new().into_boxed_slice();
        return (empty(), empty(), empty());
    }
    let mut step = Vec::with_capacity(width.len());
    let mut mask = Vec::with_capacity(width.len());
    let mut adj = Vec::with_capacity(width.len());
    for (&b, &w) in base.iter().zip(width.iter()) {
        let (s, m, a) = match w {
            W_INVALID => (0, 0, 0),
            W_OUTLIER => (ptr_bits + 32, u32::MAX, 0),
            0 => (ptr_bits, 0, b as u32),
            w => (
                ptr_bits + w,
                u32::MAX >> (32 - w),
                // fold the offset-binary bias -2^(w-1) into the base
                (b as u32).wrapping_sub(1u32 << (w - 1)),
            ),
        };
        step.push(s);
        mask.push(m);
        adj.push(a);
    }
    (step.into_boxed_slice(), mask.into_boxed_slice(), adj.into_boxed_slice())
}

/// Decode one block from `r` into `out` through a prebuilt [`DecodeLut`]
/// — the allocation-free hot path behind
/// [`BlockCodec::decompress_block`](crate::codec::BlockCodec::decompress_block)
/// for GBDI. Exactly `out.len()` bytes are reconstructed; pass a short
/// slice for ragged tail blocks.
///
/// Dispatches through the active SIMD kernel set
/// ([`crate::simd::active`]); use [`decompress_block_lut_with`] to pin a
/// specific backend (differential tests, per-ISA benches).
pub fn decompress_block_lut(r: &mut BitReader, lut: &DecodeLut, out: &mut [u8]) -> Result<()> {
    decompress_block_lut_with(r, lut, out, crate::simd::active())
}

/// [`decompress_block_lut`] with an explicit kernel vtable.
///
/// W32 GBDI payloads run a two-phase decode when `kernels` is a vector
/// backend: a serial branch-light scan splits the (inherently
/// sequential) bit stream into per-word `(pointer, raw payload)` pairs
/// using the fused `step32`/`mask32` tables, then the backend's apply
/// kernel reconstructs words in parallel as `adj32[ptr] + raw`. The
/// scan performs the **same `peek`/`consume` sequence** as the
/// reference loop below, so truncation and bad-pointer corruption
/// classify identically (pinned by the differential tests). The scalar
/// backend, W64 tables, and oversized blocks take the reference loop.
pub fn decompress_block_lut_with(
    r: &mut BitReader,
    lut: &DecodeLut,
    out: &mut [u8],
    kernels: &crate::simd::Kernels,
) -> Result<()> {
    let corrupt = |what: &str| Error::Corrupt(format!("block: {what}"));
    let tag = r.get(2).map_err(|_| corrupt("missing tag"))?;
    let ws = lut.word_size;
    match BlockMode::from_tag(tag) {
        BlockMode::Raw => {
            r.read_bytes(out).map_err(|_| corrupt("truncated raw block"))?;
        }
        BlockMode::Zero => out.fill(0),
        BlockMode::Rep => {
            let v = r.get(ws.bits()).map_err(|_| corrupt("truncated rep word"))?;
            if out.len() % ws.bytes() != 0 {
                return Err(corrupt("rep block with ragged length"));
            }
            match ws {
                WordSize::W32 => {
                    let pat = (v as u32).to_le_bytes();
                    for c in out.chunks_exact_mut(4) {
                        c.copy_from_slice(&pat);
                    }
                }
                WordSize::W64 => {
                    let pat = v.to_le_bytes();
                    for c in out.chunks_exact_mut(8) {
                        c.copy_from_slice(&pat);
                    }
                }
            }
        }
        BlockMode::Gbdi => {
            if out.len() != lut.block_bytes {
                return Err(corrupt("gbdi block with ragged length"));
            }
            if kernels.isa != crate::simd::Isa::Scalar
                && !lut.step32.is_empty()
                && lut.words_per_block <= SIMD_MAX_WORDS
            {
                return gbdi_payload_simd(r, lut, out, kernels);
            }
            let ptr_bits = lut.ptr_bits;
            let word_bits = ws.bits();
            // `width.len() == 1 << ptr_bits`, so masking with `len - 1`
            // both extracts the pointer field and proves the index in
            // range — no per-word bounds check survives optimization.
            let idx_mask = lut.width.len() - 1;
            for i in 0..lut.words_per_block {
                // One refill serves the base pointer AND its delta: peek
                // up to 57 bits, classify via the LUT, consume the fused
                // field in one step.
                let peeked = r.peek(57);
                let ptr = peeked as usize & idx_mask;
                let width = lut.width[ptr];
                let v = if width == 0 {
                    r.consume(ptr_bits).map_err(|_| corrupt("truncated base ptr"))?;
                    lut.base[ptr]
                } else if width <= 57 - ptr_bits {
                    let raw = (peeked >> ptr_bits) & ((1u64 << width) - 1);
                    r.consume(ptr_bits + width).map_err(|_| corrupt("truncated delta"))?;
                    let d = raw as i64 - (1i64 << (width - 1));
                    apply_delta(lut.base[ptr], d, ws)
                } else if width == W_OUTLIER {
                    if ptr_bits + word_bits <= 57 {
                        let v = (peeked >> ptr_bits) & ((1u64 << word_bits) - 1);
                        r.consume(ptr_bits + word_bits)
                            .map_err(|_| corrupt("truncated outlier"))?;
                        v
                    } else {
                        r.consume(ptr_bits).map_err(|_| corrupt("truncated base ptr"))?;
                        r.get(word_bits).map_err(|_| corrupt("truncated outlier"))?
                    }
                } else if width == W_INVALID {
                    return Err(corrupt("base pointer beyond table"));
                } else {
                    // wide delta field (W64 tables): unfused two-step read
                    r.consume(ptr_bits).map_err(|_| corrupt("truncated base ptr"))?;
                    let d = r.get_signed(width).map_err(|_| corrupt("truncated delta"))?;
                    apply_delta(lut.base[ptr], d, ws)
                };
                write_word(out, i, ws, v);
            }
        }
    }
    Ok(())
}

/// Two-phase GBDI payload decode (W32 fast path). Phase 1 is the
/// serial field scan — each field's bit position depends on every
/// previous field's width, so this part cannot vectorize, but the LUT
/// collapses it to one `peek`, two table loads, and one `consume` per
/// word with a single unpredictable branch (the corrupt-pointer
/// rejection). Phase 2 — the base gather, bias add, and byte store —
/// is data-parallel and runs through the backend's apply kernel.
///
/// Scratch lives on the stack: this path stays allocation-free (pinned
/// by `tests/alloc_counting.rs`).
fn gbdi_payload_simd(
    r: &mut BitReader,
    lut: &DecodeLut,
    out: &mut [u8],
    kernels: &crate::simd::Kernels,
) -> Result<()> {
    let corrupt = |what: &str| Error::Corrupt(format!("block: {what}"));
    let ptr_bits = lut.ptr_bits;
    let idx_mask = lut.width.len() - 1;
    let n = lut.words_per_block;
    debug_assert!(n <= SIMD_MAX_WORDS && out.len() == 4 * n);
    let mut ptrs = [0u32; SIMD_MAX_WORDS];
    let mut raws = [0u32; SIMD_MAX_WORDS];
    for (p, raw) in ptrs[..n].iter_mut().zip(raws[..n].iter_mut()) {
        // Same refill discipline as the reference loop: peek up to 57
        // bits (pointer + widest payload always fit), classify via the
        // fused tables, consume the whole field in one step.
        let peeked = r.peek(57);
        let ptr = peeked as usize & idx_mask;
        let step = lut.step32[ptr];
        if step == 0 {
            return Err(corrupt("base pointer beyond table"));
        }
        *p = ptr as u32;
        *raw = (peeked >> ptr_bits) as u32 & lut.mask32[ptr];
        r.consume(step).map_err(|_| corrupt("truncated gbdi field"))?;
    }
    (kernels.gbdi_apply_w32)(&lut.adj32, &ptrs[..n], &raws[..n], out);
    Ok(())
}

/// Decode one block from `r` into `out` (exactly `out.len()` bytes are
/// reconstructed; pass a short slice for ragged tail blocks).
///
/// This is the scalar **reference** decoder: one field per read, base
/// pointers bounds-checked per word. The codec's hot path is
/// [`decompress_block_lut`]; the two are asserted bit-equivalent (same
/// outputs, same error/ok classification, same bits consumed) by the
/// differential tests below and by the golden wire fixtures.
pub fn decompress_block(
    r: &mut BitReader,
    table: &GlobalBaseTable,
    config: &GbdiConfig,
    out: &mut [u8],
) -> Result<()> {
    let corrupt = |what: &str| Error::Corrupt(format!("block: {what}"));
    let tag = r.get(2).map_err(|_| corrupt("missing tag"))?;
    let ws = config.word_size;
    match BlockMode::from_tag(tag) {
        BlockMode::Raw => {
            for b in out.iter_mut() {
                *b = r.get(8).map_err(|_| corrupt("truncated raw block"))? as u8;
            }
        }
        BlockMode::Zero => out.fill(0),
        BlockMode::Rep => {
            let v = r.get(ws.bits()).map_err(|_| corrupt("truncated rep word"))?;
            if out.len() % ws.bytes() != 0 {
                return Err(corrupt("rep block with ragged length"));
            }
            for i in 0..out.len() / ws.bytes() {
                write_word(out, i, ws, v);
            }
        }
        BlockMode::Gbdi => {
            if out.len() != config.block_bytes {
                return Err(corrupt("gbdi block with ragged length"));
            }
            let ptr_bits = config.base_ptr_bits();
            let escape = config.outlier_code();
            for i in 0..config.words_per_block() {
                let ptr = r.get(ptr_bits).map_err(|_| corrupt("truncated base ptr"))?;
                let v = if ptr == escape {
                    r.get(ws.bits()).map_err(|_| corrupt("truncated outlier"))?
                } else {
                    if ptr as usize >= table.len() {
                        return Err(corrupt("base pointer beyond table"));
                    }
                    let entry = table.get(ptr as usize);
                    // Delta width is determined by the *class that was used
                    // to encode*, which the encoder chose as the smallest
                    // class fitting the delta but capped by the entry's
                    // width. The wire does not carry the class; both sides
                    // derive it identically from the entry: the entry's
                    // width class IS the field width.
                    let w = entry.width;
                    if w == 0 {
                        entry.base
                    } else {
                        let d = r.get_signed(w).map_err(|_| corrupt("truncated delta"))?;
                        apply_delta(entry.base, d, ws)
                    }
                };
                write_word(out, i, ws, v);
            }
        }
    }
    Ok(())
}

/// Decompress a full GBDI [`Container`], verifying framing. The returned
/// buffer is byte-identical to the original image. Thin wrapper over the
/// codec-agnostic [`crate::container::decompress`], kept for the quickstart
/// API surface; it additionally insists the container really is GBDI.
pub fn decompress_image(comp: &Container) -> Result<Vec<u8>> {
    if comp.codec_id != crate::codec::CodecId::Gbdi {
        return Err(Error::Corrupt(format!(
            "not a gbdi container (codec {})",
            comp.codec_id.name()
        )));
    }
    crate::container::decompress(comp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::BlockCodec;
    use crate::gbdi::encode::GbdiCodec;
    use crate::util::prng::Rng;

    fn codec() -> GbdiCodec {
        let cfg = GbdiConfig::default();
        let table = GlobalBaseTable::new(
            vec![(1000, 8), (1 << 20, 16), (3_000_000_000, 8)],
            cfg.word_size,
            1,
        );
        GbdiCodec::new(table, cfg)
    }

    fn mixed_image(len_words: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..len_words)
            .flat_map(|_| {
                let v: u32 = match rng.below(5) {
                    0 => 1000u32.wrapping_add(rng.range_i64(-127, 127) as u32),
                    1 => (1u32 << 20).wrapping_add(rng.range_i64(-30_000, 30_000) as u32),
                    2 => 3_000_000_000u32.wrapping_add(rng.range_i64(-100, 100) as u32),
                    3 => 0,
                    _ => rng.next_u32(),
                };
                v.to_le_bytes()
            })
            .collect()
    }

    #[test]
    fn roundtrip_mixed_image() {
        let image = mixed_image(4096, 11);
        let c = codec();
        let comp = c.compress_image(&image);
        assert_eq!(decompress_image(&comp).unwrap(), image);
        assert!(comp.ratio() > 1.0, "ratio {}", comp.ratio());
    }

    #[test]
    fn lut_decoder_matches_reference_per_block() {
        // differential: the fused LUT kernel and the scalar reference
        // must agree on output bytes AND bits consumed for every block
        let image = mixed_image(2048, 21);
        let c = codec();
        let comp = c.compress_image(&image);
        let mut off = 0u64;
        let mut a = vec![0u8; c.config().block_bytes];
        let mut b = vec![0u8; c.config().block_bytes];
        let lut = DecodeLut::new(c.table(), c.config());
        for (i, &bits) in comp.block_bits.iter().enumerate() {
            let byte = (off / 8) as usize;
            let sub = (off % 8) as u32;
            let mut ra = BitReader::new(&comp.payload[byte..]);
            let mut rb = BitReader::new(&comp.payload[byte..]);
            if sub != 0 {
                ra.get(sub).unwrap();
                rb.get(sub).unwrap();
            }
            decompress_block_lut(&mut ra, &lut, &mut a).unwrap();
            decompress_block(&mut rb, c.table(), c.config(), &mut b).unwrap();
            assert_eq!(a, b, "block {i}");
            assert_eq!(ra.bit_pos(), rb.bit_pos(), "block {i} bits consumed");
            assert_eq!(ra.bit_pos() - sub as usize, bits as usize, "block {i} framing");
            off += bits as u64;
        }
    }

    #[test]
    fn lut_decoder_matches_reference_under_corruption() {
        // bit-flipped payloads: both decoders must classify identically
        // (both Ok with equal bytes, or both Err), and never panic
        let image = mixed_image(512, 23);
        let c = codec();
        let comp = c.compress_image(&image);
        let lut = DecodeLut::new(c.table(), c.config());
        let mut rng = Rng::new(29);
        let mut a = vec![0u8; c.config().block_bytes];
        let mut b = vec![0u8; c.config().block_bytes];
        for _ in 0..300 {
            let mut bad = comp.payload.clone();
            let i = rng.below(bad.len() as u64) as usize;
            bad[i] ^= 1 << rng.below(8);
            // also truncate sometimes
            if rng.chance(0.3) {
                bad.truncate(rng.below(bad.len() as u64 + 1) as usize);
            }
            let mut ra = BitReader::new(&bad);
            let mut rb = BitReader::new(&bad);
            let res_a = decompress_block_lut(&mut ra, &lut, &mut a);
            let res_b = decompress_block(&mut rb, c.table(), c.config(), &mut b);
            assert_eq!(res_a.is_ok(), res_b.is_ok(), "classification diverged");
            if res_a.is_ok() {
                assert_eq!(a, b);
                assert_eq!(ra.bit_pos(), rb.bit_pos());
            }
        }
    }

    #[test]
    fn lut_rejects_out_of_table_pointer() {
        // handcraft a GBDI block whose first pointer names an entry past
        // the table: both decoders must reject it
        let c = codec(); // 4 real bases (incl. pinned zero), num_bases 64
        let lut = DecodeLut::new(c.table(), c.config());
        let mut w = crate::util::bits::BitWriter::new();
        w.put(BlockMode::Gbdi as u64, 2);
        w.put(40, c.config().base_ptr_bits()); // 40 > table.len(), != escape
        w.put(0, 57); // padding so reads don't run dry first
        let bytes = w.finish();
        let mut out = vec![0u8; c.config().block_bytes];
        let mut r = BitReader::new(&bytes);
        assert!(decompress_block_lut(&mut r, &lut, &mut out).is_err());
        let mut r = BitReader::new(&bytes);
        assert!(decompress_block(&mut r, c.table(), c.config(), &mut out).is_err());
    }

    #[test]
    fn trait_decode_uses_lut_and_roundtrips_w64() {
        // W64 tables exercise the unfused wide-field branches
        let cfg = GbdiConfig {
            word_size: crate::value::WordSize::W64,
            width_classes: vec![0, 4, 8, 16, 24, 32],
            ..Default::default()
        };
        let table = GlobalBaseTable::new(
            vec![(0x7F3A_0000_0000, 24), (5_000, 8)],
            cfg.word_size,
            1,
        );
        let c = GbdiCodec::new(table, cfg.clone());
        let mut rng = Rng::new(31);
        let image: Vec<u8> = (0..1024)
            .flat_map(|_| {
                let v: u64 = match rng.below(4) {
                    0 => 0x7F3A_0000_0000u64.wrapping_add(rng.range_i64(-400_000, 400_000) as u64),
                    1 => 5_000u64.wrapping_add(rng.range_i64(-100, 100) as u64),
                    2 => 0,
                    _ => rng.next_u64(),
                };
                v.to_le_bytes()
            })
            .collect();
        let comp = c.compress_image(&image);
        assert_eq!(decompress_image(&comp).unwrap(), image);
        // per-block trait decode (the LUT path) agrees with the reference
        let mut off = 0u64;
        let mut a = vec![0u8; cfg.block_bytes];
        let mut b = vec![0u8; cfg.block_bytes];
        for &bits in &comp.block_bits {
            let byte = (off / 8) as usize;
            let sub = (off % 8) as u32;
            let mut ra = BitReader::new(&comp.payload[byte..]);
            let mut rb = BitReader::new(&comp.payload[byte..]);
            if sub != 0 {
                ra.get(sub).unwrap();
                rb.get(sub).unwrap();
            }
            c.decompress_block(&mut ra, &mut a).unwrap();
            decompress_block(&mut rb, c.table(), c.config(), &mut b).unwrap();
            assert_eq!(a, b);
            off += bits as u64;
        }
    }

    #[test]
    fn roundtrip_ragged_image() {
        let mut image = mixed_image(100, 12);
        image.extend_from_slice(&[1, 2, 3]); // ragged tail
        let c = codec();
        let comp = c.compress_image(&image);
        assert_eq!(decompress_image(&comp).unwrap(), image);
    }

    #[test]
    fn roundtrip_empty_image() {
        let c = codec();
        let comp = c.compress_image(&[]);
        assert_eq!(decompress_image(&comp).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn truncated_payload_detected() {
        let image = mixed_image(1024, 13);
        let c = codec();
        let mut comp = c.compress_image(&image);
        comp.payload.truncate(comp.payload.len() / 2);
        assert!(decompress_image(&comp).is_err());
    }

    #[test]
    fn framing_mismatch_detected() {
        let image = mixed_image(512, 14);
        let c = codec();
        let mut comp = c.compress_image(&image);
        comp.block_bits.pop();
        assert!(decompress_image(&comp).is_err());
        let mut comp = c.compress_image(&image);
        if comp.block_bits[0] > 2 {
            comp.block_bits[0] -= 1;
            assert!(decompress_image(&comp).is_err());
        }
    }

    #[test]
    fn corrupted_payload_cannot_panic() {
        // flip bits through the payload; decode must return Ok(wrong) or
        // Err, never panic.
        let image = mixed_image(512, 15);
        let c = codec();
        let comp = c.compress_image(&image);
        let mut rng = Rng::new(16);
        for _ in 0..200 {
            let mut bad = comp.clone();
            if bad.payload.is_empty() {
                break;
            }
            let i = rng.below(bad.payload.len() as u64) as usize;
            bad.payload[i] ^= 1 << rng.below(8);
            let _ = decompress_image(&bad);
        }
    }
}
