//! A compressed main memory: cache-block-granular compressed storage with
//! sectored allocation and a metadata table, modelling what sits behind
//! the memory controller in the HPCA'22 design. Generic over any
//! [`BlockCodec`], so the bandwidth experiments sweep GBDI against BDI
//! and FPC through the same machinery.
//!
//! Layout model: each 4 KiB page is one random-access
//! [`Frame`](crate::frame::Frame) whose block spans are **aligned to the
//! sector size** — each logical 64-byte block occupies `n` sectors of
//! `sector_bytes` (8 by default). The metadata table holds the sector
//! count per block (the real hardware keeps this in a cache-able side
//! table; we charge its size in the capacity accounting). Writes
//! recompress the block in place inside its sector span; a block whose
//! encoding outgrows the span spills to the frame's patch region —
//! counted as a page re-layout, the expensive event a real controller
//! must amortize.
//!
//! Pages live in the coordinator's [`ShardedPageStore`] — the same
//! store the serving path uses — keyed by page index, so the simulator
//! exercises the production read/write paths rather than a private
//! layout. The store's automatic patch compaction is **disabled** here
//! (compaction rebuilds frames tight, which would silently discard the
//! sector-alignment slack this model charges re-layouts against).
//! Single-threaded replay uses 1 shard by default;
//! [`CompressedMemory::new_sharded`] raises the shard count for
//! concurrent experiments, and [`CompressedMemory::new_with_cache`]
//! adds the store's hot-block cache tier in front of the frames
//! (off by default so replay results stay bit-identical; see that
//! constructor for what the sector model approximates when it is on).

use crate::codec::{BlockCodec, Scratch};
use crate::coordinator::store::{ShardedPageStore, StoredPage};
use crate::frame::Frame;
use crate::{Error, Result};
use std::sync::Arc;

/// Per-memory statistics.
#[derive(Debug, Clone, Default)]
pub struct MemStats {
    /// Logical bytes stored.
    pub logical_bytes: u64,
    /// Physical payload sectors in use.
    pub used_sectors: u64,
    /// Block writes served.
    pub writes: u64,
    /// Block reads served.
    pub reads: u64,
    /// Writes that forced a page re-layout (sector-span overflow).
    pub relayouts: u64,
}

/// Compressed memory built over any [`BlockCodec`], backed by the
/// coordinator's sharded page store.
pub struct CompressedMemory {
    codec: Arc<dyn BlockCodec>,
    page_bytes: usize,
    sector_bytes: usize,
    store: ShardedPageStore,
    n_pages: usize,
    scratch: Scratch,
    stats: MemStats,
}

impl CompressedMemory {
    /// New memory with 4 KiB pages and 8-byte sectors (single store
    /// shard — the right default for single-threaded trace replay).
    pub fn new<C: BlockCodec + 'static>(codec: C) -> Self {
        Self::new_dyn(Box::new(codec))
    }

    /// [`Self::new`] from an already-boxed codec (the CLI's `--codec`
    /// path hands over a `Box<dyn BlockCodec>`).
    pub fn new_dyn(codec: Box<dyn BlockCodec>) -> Self {
        Self::new_sharded(codec, 1)
    }

    /// [`Self::new_dyn`] over a store with `shards` independently locked
    /// shards (`gbdi memsim --shards`). Shard count changes lock
    /// granularity only, never contents: trace replay results are
    /// identical for any value.
    pub fn new_sharded(codec: Box<dyn BlockCodec>, shards: usize) -> Self {
        Self::new_with_cache(codec, shards, 0)
    }

    /// [`Self::new_sharded`] with a hot-block cache tier of
    /// `cache_bytes` in front of the compressed frames (`gbdi memsim
    /// --cache-bytes`; 0 = off, the default everywhere else in this
    /// module, which keeps replay results bit-identical to the cacheless
    /// simulator). With the cache on, block *contents* are still exact,
    /// but the sector model is an approximation: a write absorbed by the
    /// cache defers recompression, so its sector growth and any
    /// re-layout are not charged to [`MemStats`] until the block is
    /// flushed — and flushes happen inside the store, invisible to the
    /// per-op accounting here. [`Self::physical_bytes`] charges the
    /// cache-resident bytes instead, so capacity numbers stay honest.
    pub fn new_with_cache(codec: Box<dyn BlockCodec>, shards: usize, cache_bytes: usize) -> Self {
        let codec: Arc<dyn BlockCodec> = Arc::from(codec);
        // no auto-compaction: a compacted frame loses its sector slack,
        // and this model's whole point is charging sector-crossing
        // growth (not store housekeeping) as the re-layout event
        let mut store = ShardedPageStore::new(shards).without_auto_compact();
        if cache_bytes > 0 {
            store = store.with_cache(cache_bytes);
        }
        store.publish_codec(Arc::clone(&codec));
        CompressedMemory {
            codec,
            page_bytes: 4096,
            sector_bytes: 8,
            store,
            n_pages: 0,
            scratch: Scratch::new(),
            stats: MemStats::default(),
        }
    }

    /// Number of store shards behind this memory.
    pub fn shard_count(&self) -> usize {
        self.store.shard_count()
    }

    /// The codec this memory compresses with.
    pub fn codec(&self) -> &dyn BlockCodec {
        self.codec.as_ref()
    }

    /// Block size (from the codec).
    pub fn block_bytes(&self) -> usize {
        self.codec.block_bytes()
    }

    /// Blocks per page.
    pub fn blocks_per_page(&self) -> usize {
        self.page_bytes / self.block_bytes()
    }

    /// Store an image; returns the base block address of the first page.
    /// The image is padded to whole pages. Each page becomes one frame
    /// with sector-aligned block spans, stored in the sharded page store
    /// under its page index.
    pub fn store_image(&mut self, image: &[u8]) -> u64 {
        let first_block = (self.n_pages * self.blocks_per_page()) as u64;
        let mut padded = image.to_vec();
        let rem = padded.len() % self.page_bytes;
        if rem != 0 {
            padded.resize(padded.len() + self.page_bytes - rem, 0);
        }
        let align_bits = (self.sector_bytes * 8) as u32;
        for page_data in padded.chunks(self.page_bytes) {
            let frame = Frame::compress_aligned(
                Arc::clone(&self.codec),
                page_data,
                align_bits,
                &mut self.scratch,
            );
            for i in 0..frame.n_blocks() {
                self.stats.used_sectors += self.sectors_for_bits(frame.block_bits(i)) as u64;
            }
            self.store.put(self.n_pages as u64, StoredPage { frame });
            self.n_pages += 1;
            self.stats.logical_bytes += self.page_bytes as u64;
        }
        first_block
    }

    fn sectors_for_bits(&self, bits: u32) -> u32 {
        let bytes = (bits as usize).div_ceil(8);
        bytes.div_ceil(self.sector_bytes) as u32
    }

    fn locate(&self, block_addr: u64) -> Result<(u64, usize)> {
        let bpp = self.blocks_per_page();
        let page = (block_addr as usize) / bpp;
        let idx = (block_addr as usize) % bpp;
        if page >= self.n_pages {
            return Err(Error::Corrupt(format!("block address {block_addr} out of range")));
        }
        Ok((page as u64, idx))
    }

    /// Read one logical block into `out` (exactly `block_bytes`), the
    /// allocation-free path a memory controller would take.
    pub fn read_block_into(&mut self, block_addr: u64, out: &mut [u8]) -> Result<()> {
        let (page, idx) = self.locate(block_addr)?;
        self.stats.reads += 1;
        self.store.read_block(page, idx, out)?;
        Ok(())
    }

    /// Read one logical block (allocating convenience wrapper).
    pub fn read_block(&mut self, block_addr: u64) -> Result<Vec<u8>> {
        let mut out = vec![0u8; self.block_bytes()];
        self.read_block_into(block_addr, &mut out)?;
        Ok(out)
    }

    /// Compressed bits a read of this block transfers on the bus.
    pub fn block_bits(&self, block_addr: u64) -> Result<u32> {
        let (page, idx) = self.locate(block_addr)?;
        self.store.block_bits(page, idx)
    }

    /// Overwrite one logical block (recompress in place through the
    /// store's write path; track sector growth and span-overflow
    /// re-layouts).
    pub fn write_block(&mut self, block_addr: u64, data: &[u8]) -> Result<()> {
        if data.len() != self.block_bytes() {
            return Err(Error::Config(format!(
                "write must be one {}-byte block",
                self.block_bytes()
            )));
        }
        let (page, idx) = self.locate(block_addr)?;
        let (old, wr) = self.store.write_block_observed(page, idx, data)?;
        if wr.spilled {
            // the page's sector layout must be rebuilt to make room
            self.stats.relayouts += 1;
        }
        let (old_s, new_s) = (self.sectors_for_bits(old), self.sectors_for_bits(wr.bits));
        self.stats.used_sectors = self.stats.used_sectors + new_s as u64 - old_s as u64;
        self.stats.writes += 1;
        Ok(())
    }

    /// Read back a whole stored image region (for verification).
    pub fn read_image(&mut self, first_block: u64, len: usize) -> Result<Vec<u8>> {
        let bb = self.block_bytes();
        let mut out = vec![0u8; len.next_multiple_of(bb.max(1))];
        let mut addr = first_block;
        for chunk in out.chunks_mut(bb) {
            self.read_block_into(addr, chunk)?;
            addr += 1;
        }
        out.truncate(len);
        Ok(out)
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> &MemStats {
        &self.stats
    }

    /// Physical bytes in use: payload sectors + metadata table (one byte
    /// per block: sector count) + the codec's shared dictionary (GBDI's
    /// global base table; stateless codecs charge nothing) + any
    /// uncompressed blocks resident in the hot-block cache tier.
    pub fn physical_bytes(&self) -> u64 {
        let blocks = (self.n_pages * self.blocks_per_page()) as u64;
        self.stats.used_sectors * self.sector_bytes as u64
            + blocks
            + self.codec.global_table().map_or(0, |t| t.serialized_len()) as u64
            + self.store.cache_resident_bytes() as u64
    }

    /// Effective capacity ratio: logical / physical — the capacity-side
    /// benefit the paper's §I motivates.
    pub fn capacity_ratio(&self) -> f64 {
        if self.stats.logical_bytes == 0 {
            return 1.0;
        }
        self.stats.logical_bytes as f64 / self.physical_bytes() as f64
    }

    /// Total logical blocks stored.
    pub fn total_blocks(&self) -> u64 {
        (self.n_pages * self.blocks_per_page()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdi::{analyze, GbdiCodec, GbdiConfig};
    use crate::workloads;

    fn memory_with(image: &[u8]) -> CompressedMemory {
        let cfg = GbdiConfig::default();
        let table = analyze::analyze_image(image, &cfg);
        CompressedMemory::new(GbdiCodec::new(table, cfg))
    }

    #[test]
    fn every_block_codec_drives_the_memory() {
        let image = workloads::by_name("mcf").unwrap().generate(1 << 15, 4);
        let cfg = GbdiConfig::default();
        for &kind in crate::codec::CodecKind::all() {
            let mut mem = CompressedMemory::new_dyn(kind.build_for_image(&image, &cfg));
            let base = mem.store_image(&image);
            assert_eq!(
                mem.read_image(base, image.len()).unwrap(),
                image,
                "{} roundtrip through memory",
                kind.name()
            );
            // write path: overwrite a block and read it back
            let block = vec![0xA5u8; mem.block_bytes()];
            mem.write_block(base, &block).unwrap();
            assert_eq!(mem.read_block(base).unwrap(), block, "{}", kind.name());
        }
    }

    #[test]
    fn store_and_read_back_exact() {
        let image = workloads::by_name("mcf").unwrap().generate(1 << 16, 3);
        let mut mem = memory_with(&image);
        let base = mem.store_image(&image);
        assert_eq!(mem.read_image(base, image.len()).unwrap(), image);
        assert!(mem.capacity_ratio() > 1.1, "capacity {}", mem.capacity_ratio());
    }

    #[test]
    fn writes_recompress_and_track_sectors() {
        let image = vec![0u8; 1 << 14];
        let mut mem = memory_with(&image);
        let base = mem.store_image(&image);
        let before = mem.stats().used_sectors;
        // overwrite a zero block with incompressible data -> sector growth
        let mut rng = crate::util::prng::Rng::new(1);
        let mut noisy = vec![0u8; 64];
        rng.fill_bytes(&mut noisy);
        mem.write_block(base + 3, &noisy).unwrap();
        assert!(mem.stats().used_sectors > before);
        assert_eq!(mem.stats().relayouts, 1);
        assert_eq!(mem.read_block(base + 3).unwrap(), noisy);
        // write it back to zeros: sectors shrink
        mem.write_block(base + 3, &vec![0u8; 64]).unwrap();
        assert_eq!(mem.stats().used_sectors, before);
    }

    #[test]
    fn sector_slack_absorbs_small_growth_without_relayout() {
        // blocks whose encoding grows but stays within its sector span
        // must rewrite in place (no re-layout) — the property the
        // sector-aligned frame layout exists for
        let mut image = vec![0u8; 1 << 14];
        for c in image.chunks_mut(4) {
            c.copy_from_slice(&1000u32.to_le_bytes());
        }
        let mut mem = memory_with(&image);
        let base = mem.store_image(&image);
        // same-shaped data (equal encoding size): in place, no relayout
        let mut block = vec![0u8; 64];
        for c in block.chunks_mut(4) {
            c.copy_from_slice(&1001u32.to_le_bytes());
        }
        mem.write_block(base + 2, &block).unwrap();
        assert_eq!(mem.stats().relayouts, 0);
        assert_eq!(mem.read_block(base + 2).unwrap(), block);
    }

    #[test]
    fn out_of_range_rejected() {
        let image = vec![0u8; 4096];
        let mut mem = memory_with(&image);
        mem.store_image(&image);
        assert!(mem.read_block(1 << 20).is_err());
        assert!(mem.write_block(0, &[0u8; 10]).is_err());
    }

    #[test]
    fn capacity_ratio_tracks_compressibility() {
        let zeros = vec![0u8; 1 << 16];
        let mut mz = memory_with(&zeros);
        mz.store_image(&zeros);
        let mut rng = crate::util::prng::Rng::new(2);
        let mut noise = vec![0u8; 1 << 16];
        rng.fill_bytes(&mut noise);
        let mut mn = memory_with(&noise);
        mn.store_image(&noise);
        assert!(mz.capacity_ratio() > 4.0, "zeros {}", mz.capacity_ratio());
        assert!(mn.capacity_ratio() < 1.05, "noise {}", mn.capacity_ratio());
        assert!(mn.capacity_ratio() > 0.85, "bounded overhead {}", mn.capacity_ratio());
    }

    #[test]
    fn ragged_image_padded_to_page() {
        let image = vec![7u8; 5000];
        let mut mem = memory_with(&image);
        let base = mem.store_image(&image);
        assert_eq!(mem.total_blocks(), 2 * 64); // 2 pages of 64 blocks
        assert_eq!(mem.read_image(base, 5000).unwrap(), image);
    }

    #[test]
    fn sharded_memory_matches_single_shard() {
        // shard count changes lock granularity only — contents, sector
        // accounting, and relayout counts must be identical
        let image = workloads::by_name("triangle_count").unwrap().generate(1 << 15, 11);
        let cfg = GbdiConfig::default();
        let build = || {
            let t = analyze::analyze_image(&image, &cfg);
            Box::new(GbdiCodec::new(t, cfg.clone())) as Box<dyn BlockCodec>
        };
        let mut a = CompressedMemory::new_dyn(build());
        let mut b = CompressedMemory::new_sharded(build(), 7);
        assert_eq!(a.shard_count(), 1);
        assert_eq!(b.shard_count(), 7);
        let base_a = a.store_image(&image);
        let base_b = b.store_image(&image);
        assert_eq!(base_a, base_b);
        let mut rng = crate::util::prng::Rng::new(13);
        let mut buf = vec![0u8; 64];
        for _ in 0..400 {
            let addr = rng.below(a.total_blocks());
            if rng.below(4) == 0 {
                rng.fill_bytes(&mut buf);
                a.write_block(addr, &buf).unwrap();
                b.write_block(addr, &buf).unwrap();
            } else {
                assert_eq!(a.read_block(addr).unwrap(), b.read_block(addr).unwrap());
            }
            assert_eq!(a.block_bits(addr).unwrap(), b.block_bits(addr).unwrap());
        }
        assert_eq!(a.stats().used_sectors, b.stats().used_sectors);
        assert_eq!(a.stats().relayouts, b.stats().relayouts);
        assert_eq!(a.physical_bytes(), b.physical_bytes());
        assert_eq!(
            a.read_image(base_a, image.len()).unwrap(),
            b.read_image(base_b, image.len()).unwrap()
        );
    }

    #[test]
    fn cached_memory_serves_identical_contents() {
        // the cache tier must never change what a replay reads back,
        // and the resident blocks must show up in the physical footprint
        let image = workloads::by_name("mcf").unwrap().generate(1 << 15, 21);
        let cfg = GbdiConfig::default();
        let build = || {
            let t = analyze::analyze_image(&image, &cfg);
            Box::new(GbdiCodec::new(t, cfg.clone())) as Box<dyn BlockCodec>
        };
        let mut plain = CompressedMemory::new_sharded(build(), 4);
        let mut cached = CompressedMemory::new_with_cache(build(), 4, 1 << 16);
        let base_p = plain.store_image(&image);
        let base_c = cached.store_image(&image);
        let mut rng = crate::util::prng::Rng::new(29);
        let mut buf = vec![0u8; 64];
        for _ in 0..400 {
            // skewed toward a small set of addresses so the cache hits
            let addr = rng.below(32);
            if rng.below(4) == 0 {
                rng.fill_bytes(&mut buf);
                plain.write_block(addr, &buf).unwrap();
                cached.write_block(addr, &buf).unwrap();
            } else {
                assert_eq!(plain.read_block(addr).unwrap(), cached.read_block(addr).unwrap());
            }
        }
        assert_eq!(
            plain.read_image(base_p, image.len()).unwrap(),
            cached.read_image(base_c, image.len()).unwrap()
        );
        assert!(cached.store.cache_resident_bytes() > 0, "cache never populated");
        // flushing the deferred writes must not change what reads see
        cached.store.flush_cache();
        assert_eq!(
            plain.read_image(base_p, image.len()).unwrap(),
            cached.read_image(base_c, image.len()).unwrap()
        );
    }
}
