//! Compressed main-memory simulator — the substrate behind experiment E7
//! (the HPCA'22 claims the paper quotes in §III: "1.5× higher bandwidth,
//! 1.1× higher performance").
//!
//! Three pieces:
//!
//! * [`mem`] — a compressed memory: pages stored as compressed blocks
//!   (any [`crate::codec::BlockCodec`] — GBDI, BDI, FPC) in fixed sectors
//!   with a metadata table (per-block sector count), capacity accounting,
//!   and transparent block read/write with recompression.
//! * [`trace`] — synthetic access traces (streaming, uniform, Zipf
//!   hot-set) over a workload image.
//! * [`bandwidth`] — a DRAM transfer model that replays a trace against
//!   raw vs compressed memory and reports bandwidth amplification plus a
//!   memory-bound speedup proxy.

pub mod bandwidth;
pub mod mem;
pub mod trace;

pub use bandwidth::{replay, DramModel, ReplayReport};
pub use mem::CompressedMemory;
pub use trace::{Access, TraceKind};
