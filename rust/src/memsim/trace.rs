//! Synthetic memory access traces: the workload side of the bandwidth
//! experiment (E7). Real controllers see a mix of streaming scans,
//! uniform pointer chasing, and hot-set (Zipf) reuse; the three kinds
//! here bracket that space.

use crate::util::prng::Rng;

/// One block-granular access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Access {
    /// Logical block address.
    pub block: u64,
    /// Write (true) or read (false).
    pub is_write: bool,
}

/// Trace shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Sequential sweep over the whole region (memcpy/scan-like).
    Streaming,
    /// Uniform random blocks (pointer chasing, hash probing).
    Uniform,
    /// Zipf-distributed hot set (cache-filtered traffic).
    Zipf {
        /// Skew exponent (≈1.0 for typical hot sets).
        exponent_milli: u32,
    },
}

impl TraceKind {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<TraceKind> {
        match s {
            "streaming" | "stream" => Some(TraceKind::Streaming),
            "uniform" | "random" => Some(TraceKind::Uniform),
            "zipf" => Some(TraceKind::Zipf { exponent_milli: 1000 }),
            _ => None,
        }
    }

    /// Display name.
    pub fn label(&self) -> String {
        match self {
            TraceKind::Streaming => "streaming".into(),
            TraceKind::Uniform => "uniform".into(),
            TraceKind::Zipf { exponent_milli } => {
                format!("zipf(s={:.2})", *exponent_milli as f64 / 1000.0)
            }
        }
    }
}

/// Generate `n` accesses over `total_blocks` with the given write
/// fraction. Deterministic in `seed`.
pub fn generate(
    kind: TraceKind,
    total_blocks: u64,
    n: usize,
    write_frac: f64,
    seed: u64,
) -> Vec<Access> {
    assert!(total_blocks > 0);
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let block = match kind {
                TraceKind::Streaming => (i as u64) % total_blocks,
                TraceKind::Uniform => rng.below(total_blocks),
                TraceKind::Zipf { exponent_milli } => {
                    rng.zipf(total_blocks, exponent_milli as f64 / 1000.0)
                }
            };
            Access { block, is_write: rng.chance(write_frac) }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streaming_is_sequential_modulo() {
        let t = generate(TraceKind::Streaming, 10, 25, 0.0, 1);
        assert_eq!(t.len(), 25);
        for (i, a) in t.iter().enumerate() {
            assert_eq!(a.block, (i as u64) % 10);
            assert!(!a.is_write);
        }
    }

    #[test]
    fn uniform_covers_range() {
        let t = generate(TraceKind::Uniform, 64, 10_000, 0.5, 2);
        let mut seen = vec![false; 64];
        let mut writes = 0;
        for a in &t {
            assert!(a.block < 64);
            seen[a.block as usize] = true;
            writes += a.is_write as u32;
        }
        assert!(seen.iter().all(|&s| s));
        let frac = writes as f64 / t.len() as f64;
        assert!((frac - 0.5).abs() < 0.03, "write frac {frac}");
    }

    #[test]
    fn zipf_is_hot_headed() {
        let t = generate(TraceKind::Zipf { exponent_milli: 1100 }, 1000, 20_000, 0.0, 3);
        let head = t.iter().filter(|a| a.block < 10).count();
        assert!(head > t.len() / 5, "head hits {head}");
    }

    #[test]
    fn parse_and_label() {
        assert_eq!(TraceKind::parse("streaming"), Some(TraceKind::Streaming));
        assert_eq!(TraceKind::parse("random"), Some(TraceKind::Uniform));
        assert!(matches!(TraceKind::parse("zipf"), Some(TraceKind::Zipf { .. })));
        assert_eq!(TraceKind::parse("bogus"), None);
        assert!(TraceKind::Zipf { exponent_milli: 1200 }.label().contains("1.20"));
    }

    #[test]
    fn deterministic() {
        let a = generate(TraceKind::Uniform, 100, 1000, 0.3, 7);
        let b = generate(TraceKind::Uniform, 100, 1000, 0.3, 7);
        assert_eq!(a, b);
    }
}
