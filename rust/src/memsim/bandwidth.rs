//! DRAM transfer model + trace replay: turns compressed block sizes into
//! the bandwidth-amplification and speedup numbers of experiment E7.
//!
//! Model: the channel moves data in `burst_bytes` beats (64 B = one raw
//! block). A compressed read moves `ceil(compressed_bytes / burst)`
//! bursts, plus a metadata burst with probability `meta_miss` (the side
//! table is cached in the controller; HPCA'22 reports high hit rates).
//! Writes move the newly compressed size. Bandwidth amplification =
//! raw bytes the trace *logically* touches / bytes actually moved.
//!
//! The speedup proxy follows the classic memory-bound scaling argument:
//! a workload spending fraction `f_mem` of its time memory-stalled speeds
//! up by `1 / (1 - f_mem + f_mem / amp)` when effective bandwidth grows
//! by `amp` — the regime the paper's "medium-high memory intensity"
//! phrase refers to.

use super::mem::CompressedMemory;
use super::trace::Access;
use crate::Result;

/// Channel / controller parameters.
#[derive(Debug, Clone)]
pub struct DramModel {
    /// Bytes per burst (matches the raw block size).
    pub burst_bytes: u64,
    /// Probability a block's metadata lookup misses the controller cache
    /// and costs one extra burst.
    pub meta_miss: f64,
}

impl Default for DramModel {
    fn default() -> Self {
        DramModel { burst_bytes: 64, meta_miss: 0.05 }
    }
}

/// Replay outcome.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// Accesses replayed.
    pub accesses: u64,
    /// Logical bytes touched (accesses × block size).
    pub logical_bytes: u64,
    /// Bytes moved by the compressed memory (incl. metadata bursts).
    pub compressed_bytes: u64,
    /// Bandwidth amplification (logical / compressed).
    pub amplification: f64,
    /// Speedup proxy at the given memory-bound fraction.
    pub speedup_at: Vec<(f64, f64)>,
}

impl ReplayReport {
    /// Speedup for a memory-stall fraction `f_mem` given this
    /// amplification.
    pub fn speedup(&self, f_mem: f64) -> f64 {
        let amp = self.amplification.max(1e-9);
        1.0 / ((1.0 - f_mem) + f_mem / amp)
    }
}

/// Replay a trace against compressed memory under the DRAM model.
///
/// Every access really goes through the block-granular compressed path
/// ([`CompressedMemory::read_block_into`] /
/// [`CompressedMemory::write_block`]): reads decode the line, writes
/// read-modify-write it — so the transfer accounting below charges
/// exactly the bits the memory actually served, and the replay cost is
/// the real per-line decode cost, not a table lookup.
///
/// `meta_miss` is charged deterministically as an expected value (no
/// extra randomness: replay is reproducible).
pub fn replay(mem: &mut CompressedMemory, trace: &[Access], model: &DramModel) -> Result<ReplayReport> {
    let block_bytes = mem.block_bytes() as u64;
    let total = mem.total_blocks();
    let mut line = vec![0u8; block_bytes as usize];
    let mut moved_bursts_x1000: u64 = 0; // fixed-point: bursts * 1000
    for a in trace {
        let addr = a.block % total;
        mem.read_block_into(addr, &mut line)?;
        let bits = mem.block_bits(addr)?;
        let bytes = (bits as u64).div_ceil(8);
        let bursts = bytes.div_ceil(model.burst_bytes);
        moved_bursts_x1000 += bursts * 1000 + (model.meta_miss * 1000.0) as u64;
        if a.is_write {
            // write path: read-modify-write the same line back through
            // the compressor; moves the (re)compressed size again
            mem.write_block(addr, &line)?;
            let wbits = mem.block_bits(addr)?;
            let wbytes = (wbits as u64).div_ceil(8);
            moved_bursts_x1000 += wbytes.div_ceil(model.burst_bytes) * 1000;
        }
    }
    let logical: u64 = trace
        .iter()
        .map(|a| if a.is_write { 2 * block_bytes } else { block_bytes })
        .sum();
    let compressed = moved_bursts_x1000 * model.burst_bytes / 1000;
    let amplification = logical as f64 / compressed.max(1) as f64;
    let mut report = ReplayReport {
        accesses: trace.len() as u64,
        logical_bytes: logical,
        compressed_bytes: compressed,
        amplification,
        speedup_at: Vec::new(),
    };
    report.speedup_at =
        [0.2, 0.4, 0.6, 0.8].iter().map(|&f| (f, report.speedup(f))).collect();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdi::{analyze, GbdiCodec, GbdiConfig};
    use crate::memsim::trace::{generate, TraceKind};
    use crate::workloads;

    fn setup(image: &[u8]) -> CompressedMemory {
        let cfg = GbdiConfig::default();
        let table = analyze::analyze_image(image, &cfg);
        let mut mem = CompressedMemory::new(GbdiCodec::new(table, cfg));
        mem.store_image(image);
        mem
    }

    #[test]
    fn zeros_amplify_hugely() {
        let mut mem = setup(&vec![0u8; 1 << 16]);
        let trace = generate(TraceKind::Streaming, mem.total_blocks(), 4096, 0.0, 1);
        let rep = replay(&mut mem, &trace, &DramModel::default()).unwrap();
        // zero blocks still cost one burst + metadata, so amp ≈ 1/(1+0.05)... no:
        // one burst minimum per access -> amp ≈ 64 / (64*1.05) ≈ 0.95? No -
        // zero block = 2 bits -> 1 burst. raw = 1 burst. metadata 0.05.
        // Amplification comes from multi-burst raw blocks vs 1-burst
        // compressed; with burst == block size both cost 1 burst and amp ~ 0.95.
        // This documents the model honestly: block-granular DRAM cannot gain
        // on single-block reads; gains need burst_bytes < block or prefetch.
        assert!(rep.amplification > 0.9 && rep.amplification < 1.05, "amp {}", rep.amplification);
    }

    #[test]
    fn fine_bursts_show_compression_gains() {
        // 16-byte bursts (HBM-like small beats): compressed blocks move fewer
        let image = workloads::by_name("triangle_count").unwrap().generate(1 << 18, 5);
        let mut mem = setup(&image);
        let model = DramModel { burst_bytes: 16, meta_miss: 0.05 };
        let trace = generate(TraceKind::Streaming, mem.total_blocks(), 8192, 0.0, 2);
        let rep = replay(&mut mem, &trace, &model).unwrap();
        assert!(rep.amplification > 1.15, "amp {}", rep.amplification);
        // speedup proxy is monotone in f_mem
        assert!(rep.speedup(0.8) > rep.speedup(0.2));
        assert!(rep.speedup(0.0) == 1.0);
    }

    #[test]
    fn incompressible_never_amplifies_above_one() {
        let mut rng = crate::util::prng::Rng::new(3);
        let mut noise = vec![0u8; 1 << 16];
        rng.fill_bytes(&mut noise);
        let mut mem = setup(&noise);
        let model = DramModel { burst_bytes: 16, meta_miss: 0.05 };
        let trace = generate(TraceKind::Uniform, mem.total_blocks(), 4096, 0.2, 3);
        let rep = replay(&mut mem, &trace, &model).unwrap();
        assert!(rep.amplification <= 1.02, "amp {}", rep.amplification);
        // raw fallback costs the 2-bit tag, which rounds a 64-byte block up
        // to a 5th 16-byte burst: the model honestly charges ~0.8×
        assert!(rep.amplification > 0.75, "bounded penalty {}", rep.amplification);
    }

    #[test]
    fn writes_count_double() {
        let image = vec![0u8; 1 << 14];
        let mut mem = setup(&image);
        let reads = generate(TraceKind::Streaming, mem.total_blocks(), 1000, 0.0, 4);
        let writes = generate(TraceKind::Streaming, mem.total_blocks(), 1000, 1.0, 4);
        let m = DramModel::default();
        let rr = replay(&mut mem, &reads, &m).unwrap();
        let rw = replay(&mut mem, &writes, &m).unwrap();
        assert!(rw.logical_bytes == 2 * rr.logical_bytes);
    }

    #[test]
    fn report_fields_consistent() {
        let image = workloads::by_name("mcf").unwrap().generate(1 << 16, 9);
        let mut mem = setup(&image);
        let trace = generate(TraceKind::Zipf { exponent_milli: 1000 }, mem.total_blocks(), 2000, 0.1, 5);
        let rep = replay(&mut mem, &trace, &DramModel { burst_bytes: 16, meta_miss: 0.0 }).unwrap();
        assert_eq!(rep.accesses, 2000);
        assert_eq!(rep.speedup_at.len(), 4);
        let recomputed = rep.logical_bytes as f64 / rep.compressed_bytes as f64;
        assert!((recomputed - rep.amplification).abs() < 1e-9);
    }
}
