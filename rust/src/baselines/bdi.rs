//! Base-Delta-Immediate compression (Pekhimenko et al., PACT'12) — the
//! per-block baseline GBDI generalizes. Each 64-byte block tries a fixed
//! menu of (base size Δ delta size) encodings **plus an implicit zero
//! base** (the "Immediate" part): every word is either `base + small Δ`
//! or `0 + small Δ`, selected by a per-word mask bit.
//!
//! Wire format per block: 4-bit encoding id, then for non-trivial
//! encodings: the base (k bytes), the per-word zero-base mask, and one
//! d-byte delta per word. Ragged tail blocks are stored raw.

use super::Codec;
use crate::util::bits::{BitReader, BitWriter};
use crate::{Error, Result};

/// The eight BDI encodings plus raw/zero/rep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Enc {
    Zeros = 0,
    Rep8 = 1,
    B8D1 = 2,
    B8D2 = 3,
    B8D4 = 4,
    B4D1 = 5,
    B4D2 = 6,
    B2D1 = 7,
    Raw = 8,
}

impl Enc {
    fn from_id(id: u64) -> Option<Enc> {
        Some(match id {
            0 => Enc::Zeros,
            1 => Enc::Rep8,
            2 => Enc::B8D1,
            3 => Enc::B8D2,
            4 => Enc::B8D4,
            5 => Enc::B4D1,
            6 => Enc::B4D2,
            7 => Enc::B2D1,
            8 => Enc::Raw,
            _ => return None,
        })
    }

    /// (base bytes, delta bytes) for the delta encodings.
    fn kd(self) -> Option<(usize, usize)> {
        Some(match self {
            Enc::B8D1 => (8, 1),
            Enc::B8D2 => (8, 2),
            Enc::B8D4 => (8, 4),
            Enc::B4D1 => (4, 1),
            Enc::B4D2 => (4, 2),
            Enc::B2D1 => (2, 1),
            _ => return None,
        })
    }
}

/// BDI codec over fixed-size blocks.
pub struct Bdi {
    /// Block size in bytes (64 in the paper).
    pub block_bytes: usize,
}

impl Default for Bdi {
    fn default() -> Self {
        Bdi { block_bytes: 64 }
    }
}

fn read_le(block: &[u8], i: usize, k: usize) -> u64 {
    let mut v = 0u64;
    for b in 0..k {
        v |= (block[i * k + b] as u64) << (8 * b);
    }
    v
}

fn sign_fits(delta: u64, k: usize, d: usize) -> bool {
    // delta computed in k-byte wrapping arithmetic; check it sign-fits in d bytes
    let bits = 8 * d as u32;
    let kbits = 8 * k as u32;
    // sign-extend delta from kbits to 64
    let sd = ((delta << (64 - kbits)) as i64) >> (64 - kbits);
    let bias = 1i64 << (bits - 1);
    sd >= -bias && sd < bias
}

/// Feasibility scan for the (k, d) encoding: every word must fit
/// against either the zero base or the block base (the first word that
/// misses the zero base). Plan-free — the selection loop runs this for
/// the whole encoding menu without materializing anything. This is the
/// scalar reference the SIMD kernels ([`crate::simd::Kernels::bdi_fits`])
/// are differentially tested against.
pub(crate) fn plan_fits(block: &[u8], k: usize, d: usize) -> bool {
    plan_fits_from(block, k, d, 0, None)
}

/// [`plan_fits`] resumed from word index `start` with carried base
/// state — the scalar tail every vector kernel falls back to after its
/// full-register chunks (`base` is the block base if a preceding word
/// already latched one).
pub(crate) fn plan_fits_from(
    block: &[u8],
    k: usize,
    d: usize,
    start: usize,
    mut base: Option<u64>,
) -> bool {
    let n = block.len() / k;
    let kbits = 8 * k as u32;
    for i in start..n {
        let v = read_le(block, i, k);
        if sign_fits(v, k, d) {
            continue; // zero base
        }
        let b = *base.get_or_insert(v);
        if !sign_fits(v.wrapping_sub(b) & mask_bits(kbits), k, d) {
            return false;
        }
    }
    true
}

impl Bdi {
    /// Materialize the per-word (zero-base?, delta) plan for an encoding
    /// [`plan_fits`] already accepted, into a caller-owned buffer
    /// (cleared first). Returns the block base — or `None` if the
    /// encoding does not actually fit, so a future divergence from the
    /// feasibility scan degrades to the raw fallback instead of emitting
    /// a corrupt stream.
    fn plan_into(block: &[u8], k: usize, d: usize, plan: &mut Vec<(bool, u64)>) -> Option<u64> {
        let n = block.len() / k;
        let kbits = 8 * k as u32;
        let dmask = mask_bits(8 * d as u32);
        let mut base: Option<u64> = None;
        plan.clear();
        for i in 0..n {
            let v = read_le(block, i, k);
            if sign_fits(v, k, d) {
                plan.push((true, v & dmask));
                continue;
            }
            let b = *base.get_or_insert(v);
            let delta = v.wrapping_sub(b) & mask_bits(kbits);
            if !sign_fits(delta, k, d) {
                debug_assert!(false, "plan_into on an infeasible encoding");
                return None;
            }
            plan.push((false, delta & dmask));
        }
        Some(base.unwrap_or(0))
    }

    /// Size in bits of a (k, d) encoding for an n-word block: id + base +
    /// mask + deltas.
    fn enc_bits(block_len: usize, k: usize, d: usize) -> u64 {
        let n = (block_len / k) as u64;
        4 + 8 * k as u64 + n + 8 * d as u64 * n
    }

    fn encode_block(&self, block: &[u8], w: &mut BitWriter) {
        let mut plan = Vec::new();
        self.encode_block_with(block, w, &mut plan);
    }

    /// [`Self::encode_block`] with a caller-owned plan buffer (the
    /// [`crate::codec::Scratch`]-aware hot path: zero allocations once
    /// the buffer reaches its steady-state size).
    fn encode_block_with(&self, block: &[u8], w: &mut BitWriter, plan: &mut Vec<(bool, u64)>) {
        let kernels = crate::simd::active();
        // fast paths
        if block.len() == self.block_bytes {
            if (kernels.all_zero)(block) {
                w.put(Enc::Zeros as u64, 4);
                return;
            }
            if block.len() % 8 == 0 && (kernels.rep_words)(block, 8) {
                w.put(Enc::Rep8 as u64, 4);
                w.put(read_le(block, 0, 8), 64);
                return;
            }
            // pick the smallest fitting delta encoding: one plan-free
            // feasibility pass over the menu, then materialize only the
            // winner into the reusable buffer
            let mut best: Option<(Enc, u64)> = None;
            for enc in [Enc::B8D1, Enc::B4D1, Enc::B8D2, Enc::B2D1, Enc::B4D2, Enc::B8D4] {
                let (k, d) = enc.kd().unwrap();
                if block.len() % k != 0 {
                    continue;
                }
                let bits = Self::enc_bits(block.len(), k, d);
                if best.map_or(true, |(_, bb)| bits < bb) && (kernels.bdi_fits)(block, k, d) {
                    best = Some((enc, bits));
                }
            }
            if let Some((enc, bits)) = best {
                if bits < 4 + 8 * block.len() as u64 {
                    let (k, d) = enc.kd().unwrap();
                    if let Some(base) = Self::plan_into(block, k, d, plan) {
                        w.put(enc as u64, 4);
                        w.put(base & mask_bits(8 * k as u32), 8 * k as u32);
                        for &(zero, _) in plan.iter() {
                            w.put_bit(zero);
                        }
                        for &(_, delta) in plan.iter() {
                            w.put(delta, 8 * d as u32);
                        }
                        return;
                    }
                }
            }
        }
        // raw fallback (also ragged tails): bulk byte append
        w.put(Enc::Raw as u64, 4);
        w.put_bytes(block);
    }

    fn decode_block(&self, r: &mut BitReader, out: &mut [u8]) -> Result<()> {
        let corrupt = |m: &str| Error::Corrupt(format!("bdi: {m}"));
        let id = r.get(4).map_err(|_| corrupt("missing id"))?;
        let enc = Enc::from_id(id).ok_or_else(|| corrupt("bad encoding id"))?;
        match enc {
            Enc::Zeros => out.fill(0),
            Enc::Rep8 => {
                let v = r.get(64).map_err(|_| corrupt("truncated rep"))?;
                if out.len() % 8 != 0 {
                    return Err(corrupt("rep8 on ragged block"));
                }
                for c in out.chunks_mut(8) {
                    c.copy_from_slice(&v.to_le_bytes());
                }
            }
            Enc::Raw => {
                r.read_bytes(out).map_err(|_| corrupt("truncated raw"))?;
            }
            _ => {
                let (k, d) = enc.kd().unwrap();
                if out.len() % k != 0 {
                    return Err(corrupt("delta enc on ragged block"));
                }
                let n = out.len() / k;
                let kbits = 8 * k as u32;
                let dbits = 8 * d as u32;
                let base = r.get(kbits).map_err(|_| corrupt("truncated base"))?;
                // The zero-base mask precedes the deltas on the wire. Run
                // two cursors instead of buffering the mask: `r` walks the
                // mask in up-to-57-bit gulps while a clone walks the delta
                // stream just past it — allocation-free for any block size
                // (this is the per-line read path of Frame::read_block).
                let mut dr = r.clone();
                let mut skip = n as u64;
                while skip > 0 {
                    let take = skip.min(57) as u32;
                    dr.get(take).map_err(|_| corrupt("truncated mask"))?;
                    skip -= take as u64;
                }
                let mut i = 0usize;
                while i < n {
                    let take = (n - i).min(57);
                    let mut m = r.get(take as u32).map_err(|_| corrupt("truncated mask"))?;
                    for _ in 0..take {
                        let delta = dr.get(dbits).map_err(|_| corrupt("truncated delta"))?;
                        // sign-extend delta from dbits to kbits
                        let sd = ((delta << (64 - dbits)) as i64 >> (64 - dbits)) as u64;
                        let v = if m & 1 != 0 { sd } else { base.wrapping_add(sd) }
                            & mask_bits(kbits);
                        out[i * k..(i + 1) * k].copy_from_slice(&v.to_le_bytes()[..k]);
                        m >>= 1;
                        i += 1;
                    }
                }
                *r = dr;
            }
        }
        Ok(())
    }
}

#[inline]
fn mask_bits(bits: u32) -> u64 {
    if bits >= 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

impl crate::codec::BlockCodec for Bdi {
    fn name(&self) -> &'static str {
        "bdi"
    }

    fn codec_id(&self) -> crate::codec::CodecId {
        crate::codec::CodecId::Bdi
    }

    fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    fn compress_block(&self, block: &[u8], w: &mut BitWriter) -> u32 {
        let start = w.bit_len();
        self.encode_block(block, w);
        (w.bit_len() - start) as u32
    }

    fn compress_block_with(
        &self,
        block: &[u8],
        w: &mut BitWriter,
        scratch: &mut crate::codec::Scratch,
    ) -> u32 {
        let start = w.bit_len();
        self.encode_block_with(block, w, &mut scratch.bdi_plan);
        (w.bit_len() - start) as u32
    }

    fn decompress_block(&self, r: &mut BitReader<'_>, out: &mut [u8]) -> Result<()> {
        self.decode_block(r, out)
    }

    fn config_bytes(&self) -> Vec<u8> {
        crate::codec::block_bytes_config(self.block_bytes)
    }
}

impl Codec for Bdi {
    fn name(&self) -> &'static str {
        "bdi"
    }

    fn compress(&self, data: &[u8]) -> Vec<u8> {
        let mut w = BitWriter::with_capacity(data.len() / 2 + 16);
        for block in data.chunks(self.block_bytes) {
            self.encode_block(block, &mut w);
        }
        w.finish()
    }

    fn decompress(&self, comp: &[u8], original_len: usize) -> Result<Vec<u8>> {
        let mut out = vec![0u8; original_len];
        let mut r = BitReader::new(comp);
        for chunk in out.chunks_mut(self.block_bytes) {
            self.decode_block(&mut r, chunk)?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testsupport::roundtrip_battery;
    use crate::util::prng::Rng;

    #[test]
    fn battery() {
        roundtrip_battery(&Bdi::default());
    }

    #[test]
    fn zeros_block_is_four_bits() {
        let bdi = Bdi::default();
        let comp = bdi.compress(&[0u8; 64]);
        assert_eq!(comp.len(), 1); // 4 bits padded
    }

    #[test]
    fn narrow_values_compress() {
        // u64 words with small magnitudes -> B8D1: 4 + 64 + 8 + 64 bits = 17.5B vs 64B
        let mut data = Vec::new();
        for i in 0..8u64 {
            data.extend_from_slice(&(1_000_000 + i).to_le_bytes());
        }
        let bdi = Bdi::default();
        let comp = bdi.compress(&data);
        assert!(comp.len() < 20, "compressed {} bytes", comp.len());
        assert_eq!(bdi.decompress(&comp, data.len()).unwrap(), data);
    }

    #[test]
    fn pointer_like_blocks_compress() {
        // realistic: 8 pointers into the same region + small ints mixed
        let mut rng = Rng::new(4);
        let mut data = Vec::new();
        for _ in 0..64 {
            let heap = 0x7F3A_0000_0000u64;
            for i in 0..4 {
                data.extend_from_slice(&(heap + rng.below(4096) * 8 + i).to_le_bytes());
            }
            for _ in 0..4 {
                data.extend_from_slice(&(rng.below(100) as u64).to_le_bytes());
            }
        }
        let bdi = Bdi::default();
        let r = crate::baselines::ratio_of(&bdi, &data);
        assert!(r > 2.0, "ratio {r}");
        let comp = bdi.compress(&data);
        assert_eq!(bdi.decompress(&comp, data.len()).unwrap(), data);
    }

    #[test]
    fn incompressible_expands_bounded() {
        let mut rng = Rng::new(5);
        let mut data = vec![0u8; 4096];
        rng.fill_bytes(&mut data);
        let bdi = Bdi::default();
        let comp = bdi.compress(&data);
        // at most 4 bits per 64-byte block of overhead
        assert!(comp.len() <= data.len() + data.len() / 64 + 8);
        assert_eq!(bdi.decompress(&comp, data.len()).unwrap(), data);
    }

    #[test]
    fn truncated_stream_errors() {
        let bdi = Bdi::default();
        let data = vec![9u8; 640];
        let comp = bdi.compress(&data);
        assert!(bdi.decompress(&comp[..2], 640).is_err());
    }

    #[test]
    fn random_fuzz_roundtrip() {
        let mut rng = Rng::new(6);
        let bdi = Bdi::default();
        for _ in 0..100 {
            let len = rng.below(2048) as usize;
            let mut data = vec![0u8; len];
            // half structured, half random
            if rng.chance(0.5) {
                rng.fill_bytes(&mut data);
            } else {
                for c in data.chunks_mut(8) {
                    let v = 0xAA00_0000u64 + rng.below(128);
                    let n = c.len();
                    c.copy_from_slice(&v.to_le_bytes()[..n]);
                }
            }
            let comp = bdi.compress(&data);
            assert_eq!(bdi.decompress(&comp, len).unwrap(), data);
        }
    }
}
