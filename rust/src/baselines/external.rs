//! General-purpose comparators from the paper's intro: gzip (DEFLATE via
//! flate2) and zstd. These anchor the E3 table's "heavyweight software
//! codec" end — higher ratios, far higher latency than the
//! hardware-amenable block codecs.

use super::Codec;
use crate::{Error, Result};
use std::io::{Read, Write};

/// gzip at a configurable level (default 6, the usual tradeoff point).
pub struct Gzip {
    /// Compression level 0-9.
    pub level: u32,
}

impl Default for Gzip {
    fn default() -> Self {
        Gzip { level: 6 }
    }
}

impl Codec for Gzip {
    fn name(&self) -> &'static str {
        "gzip"
    }

    fn compress(&self, data: &[u8]) -> Vec<u8> {
        let mut enc = flate2::write::GzEncoder::new(
            Vec::with_capacity(data.len() / 2 + 64),
            flate2::Compression::new(self.level),
        );
        enc.write_all(data).expect("in-memory gzip write");
        enc.finish().expect("in-memory gzip finish")
    }

    fn decompress(&self, comp: &[u8], original_len: usize) -> Result<Vec<u8>> {
        let mut dec = flate2::read::GzDecoder::new(comp);
        let mut out = Vec::with_capacity(original_len);
        dec.read_to_end(&mut out).map_err(|e| Error::Corrupt(format!("gzip: {e}")))?;
        if out.len() != original_len {
            return Err(Error::Corrupt(format!(
                "gzip: expected {original_len} bytes, got {}",
                out.len()
            )));
        }
        Ok(out)
    }
}

/// zstd at a configurable level (default 3).
pub struct Zstd {
    /// Compression level 1-22.
    pub level: i32,
}

impl Default for Zstd {
    fn default() -> Self {
        Zstd { level: 3 }
    }
}

impl Codec for Zstd {
    fn name(&self) -> &'static str {
        "zstd"
    }

    fn compress(&self, data: &[u8]) -> Vec<u8> {
        zstd::bulk::compress(data, self.level).expect("in-memory zstd")
    }

    fn decompress(&self, comp: &[u8], original_len: usize) -> Result<Vec<u8>> {
        let out = zstd::bulk::decompress(comp, original_len)
            .map_err(|e| Error::Corrupt(format!("zstd: {e}")))?;
        if out.len() != original_len {
            return Err(Error::Corrupt(format!(
                "zstd: expected {original_len} bytes, got {}",
                out.len()
            )));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testsupport::roundtrip_battery;

    #[test]
    fn gzip_battery() {
        roundtrip_battery(&Gzip::default());
    }

    #[test]
    fn zstd_battery() {
        roundtrip_battery(&Zstd::default());
    }

    #[test]
    fn corrupt_streams_rejected() {
        let data = vec![5u8; 1000];
        let comp = Gzip::default().compress(&data);
        assert!(Gzip::default().decompress(&comp[..comp.len() / 2], 1000).is_err());
        let comp = Zstd::default().compress(&data);
        assert!(Zstd::default().decompress(&comp[..comp.len() / 2], 1000).is_err());
    }

    #[test]
    fn levels_change_output() {
        let data: Vec<u8> = (0..10_000u32).flat_map(|i| (i % 256).to_le_bytes()).collect();
        let fast = Gzip { level: 1 }.compress(&data);
        let best = Gzip { level: 9 }.compress(&data);
        assert!(best.len() <= fast.len());
    }
}
