//! Canonical Huffman coding over bytes — the paper's "Huffman coding"
//! baseline (§1.1). Header is the 256 canonical code lengths; codes are
//! emitted MSB-first so the canonical first-code decoder walks one bit at
//! a time.

use super::Codec;
use crate::util::bits::{BitReader, BitWriter};
use crate::{Error, Result};

/// Canonical Huffman byte coder.
pub struct Huffman;

/// Maximum code length we allow; distributions deeper than this get their
/// counts flattened and the tree rebuilt (bounded iterations).
const MAX_LEN: u32 = 32;

/// Build Huffman code lengths for `counts` (only symbols with count > 0
/// get codes). Returns 256 lengths (0 = unused symbol).
fn code_lengths(counts: &[u64; 256]) -> [u8; 256] {
    #[derive(Clone)]
    struct Node {
        weight: u64,
        // leaf symbol or internal children indices
        sym: Option<u8>,
        kids: Option<(usize, usize)>,
    }
    let mut counts = *counts;
    loop {
        let mut nodes: Vec<Node> = Vec::new();
        let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(u64, usize)>> =
            std::collections::BinaryHeap::new();
        for s in 0..256 {
            if counts[s] > 0 {
                nodes.push(Node { weight: counts[s], sym: Some(s as u8), kids: None });
                heap.push(std::cmp::Reverse((counts[s], nodes.len() - 1)));
            }
        }
        let mut lens = [0u8; 256];
        match heap.len() {
            0 => return lens,
            1 => {
                let std::cmp::Reverse((_, i)) = heap.pop().unwrap();
                lens[nodes[i].sym.unwrap() as usize] = 1;
                return lens;
            }
            _ => {}
        }
        while heap.len() > 1 {
            let std::cmp::Reverse((wa, a)) = heap.pop().unwrap();
            let std::cmp::Reverse((wb, b)) = heap.pop().unwrap();
            nodes.push(Node { weight: wa + wb, sym: None, kids: Some((a, b)) });
            heap.push(std::cmp::Reverse((wa + wb, nodes.len() - 1)));
        }
        // depth-assign
        let root = heap.pop().unwrap().0 .1;
        let mut stack = vec![(root, 0u32)];
        let mut too_deep = false;
        while let Some((n, depth)) = stack.pop() {
            match (nodes[n].sym, nodes[n].kids) {
                (Some(s), _) => {
                    if depth > MAX_LEN {
                        too_deep = true;
                        break;
                    }
                    lens[s as usize] = depth.max(1) as u8;
                }
                (None, Some((a, b))) => {
                    stack.push((a, depth + 1));
                    stack.push((b, depth + 1));
                }
                _ => unreachable!(),
            }
        }
        if !too_deep {
            return lens;
        }
        // flatten the distribution and retry (guaranteed to terminate:
        // weights converge towards uniform, whose depth is 8)
        for c in counts.iter_mut() {
            if *c > 0 {
                *c = *c / 2 + 1;
            }
        }
    }
}

/// Canonical code assignment from lengths: symbols sorted by (length,
/// value) get consecutive codes. Returns (code, len) per symbol.
fn canonical_codes(lens: &[u8; 256]) -> Vec<(u32, u8)> {
    let mut order: Vec<u8> = (0u16..256).map(|s| s as u8).filter(|&s| lens[s as usize] > 0).collect();
    order.sort_by_key(|&s| (lens[s as usize], s));
    let mut codes = vec![(0u32, 0u8); 256];
    let mut code = 0u32;
    let mut prev_len = 0u8;
    for &s in &order {
        let l = lens[s as usize];
        code <<= l - prev_len;
        codes[s as usize] = (code, l);
        code += 1;
        prev_len = l;
    }
    codes
}

/// Canonical decoder tables: for each length, the first code and the
/// symbol-table offset.
struct Decoder {
    first_code: [u32; (MAX_LEN + 1) as usize],
    offset: [u32; (MAX_LEN + 1) as usize],
    count: [u32; (MAX_LEN + 1) as usize],
    symbols: Vec<u8>, // sorted by (len, sym)
}

impl Decoder {
    fn new(lens: &[u8; 256]) -> Decoder {
        let mut order: Vec<u8> =
            (0u16..256).map(|s| s as u8).filter(|&s| lens[s as usize] > 0).collect();
        order.sort_by_key(|&s| (lens[s as usize], s));
        let mut count = [0u32; (MAX_LEN + 1) as usize];
        for &s in &order {
            count[lens[s as usize] as usize] += 1;
        }
        let mut first_code = [0u32; (MAX_LEN + 1) as usize];
        let mut offset = [0u32; (MAX_LEN + 1) as usize];
        let mut code = 0u32;
        let mut off = 0u32;
        for l in 1..=MAX_LEN as usize {
            first_code[l] = code;
            offset[l] = off;
            code = (code + count[l]) << 1;
            off += count[l];
        }
        Decoder { first_code, offset, count, symbols: order }
    }

    fn decode(&self, r: &mut BitReader) -> Result<u8> {
        let mut code = 0u32;
        for l in 1..=MAX_LEN as usize {
            code = (code << 1)
                | r.get_bit().map_err(|_| Error::Corrupt("huffman: truncated code".into()))? as u32;
            if self.count[l] > 0 && code.wrapping_sub(self.first_code[l]) < self.count[l] {
                let idx = self.offset[l] + (code - self.first_code[l]);
                return Ok(self.symbols[idx as usize]);
            }
        }
        Err(Error::Corrupt("huffman: invalid code".into()))
    }
}

impl Codec for Huffman {
    fn name(&self) -> &'static str {
        "huffman"
    }

    fn compress(&self, data: &[u8]) -> Vec<u8> {
        let mut counts = [0u64; 256];
        for &b in data {
            counts[b as usize] += 1;
        }
        let lens = code_lengths(&counts);
        let codes = canonical_codes(&lens);
        let mut out = Vec::with_capacity(256 + data.len() / 2 + 8);
        out.extend_from_slice(&lens); // 256-byte header
        // Codes are canonical-MSB-first on the wire; the writer is
        // LSB-first, so pre-reverse each code once and emit it as a
        // single `put` instead of one `put_bit` per code bit. The bit
        // sequence is identical.
        let mut fast = [(0u64, 0u32); 256];
        for (s, f) in fast.iter_mut().enumerate() {
            let (code, l) = codes[s];
            if l > 0 {
                *f = ((code as u64).reverse_bits() >> (64 - l as u32), l as u32);
            }
        }
        let mut w = BitWriter::with_capacity(data.len() / 2);
        for &b in data {
            let (v, l) = fast[b as usize];
            w.put(v, l);
        }
        out.extend_from_slice(&w.finish());
        out
    }

    fn decompress(&self, comp: &[u8], original_len: usize) -> Result<Vec<u8>> {
        if original_len == 0 {
            return Ok(Vec::new());
        }
        if comp.len() < 256 {
            return Err(Error::Corrupt("huffman: missing header".into()));
        }
        let mut lens = [0u8; 256];
        lens.copy_from_slice(&comp[..256]);
        if lens.iter().any(|&l| l as u32 > MAX_LEN) {
            return Err(Error::Corrupt("huffman: bad code length".into()));
        }
        let dec = Decoder::new(&lens);
        if dec.symbols.is_empty() {
            return Err(Error::Corrupt("huffman: empty code table".into()));
        }
        let mut r = BitReader::new(&comp[256..]);
        let mut out = Vec::with_capacity(original_len);
        for _ in 0..original_len {
            out.push(dec.decode(&mut r)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testsupport::roundtrip_battery;
    use crate::util::prng::Rng;

    #[test]
    fn battery() {
        roundtrip_battery(&Huffman);
    }

    #[test]
    fn skewed_distribution_compresses() {
        let mut rng = Rng::new(10);
        let data: Vec<u8> = (0..1 << 16)
            .map(|_| if rng.chance(0.9) { 0u8 } else { rng.next_u32() as u8 })
            .collect();
        let r = crate::baselines::ratio_of(&Huffman, &data);
        assert!(r > 2.0, "ratio {r}");
    }

    #[test]
    fn single_symbol_stream() {
        let data = vec![42u8; 10_000];
        let comp = Huffman.compress(&data);
        // 256 header + 10000 bits
        assert!(comp.len() < 256 + 1260);
        assert_eq!(Huffman.decompress(&comp, data.len()).unwrap(), data);
    }

    #[test]
    fn uniform_bytes_near_incompressible() {
        let mut rng = Rng::new(11);
        let mut data = vec![0u8; 1 << 15];
        rng.fill_bytes(&mut data);
        let comp = Huffman.compress(&data);
        assert!(comp.len() as f64 > data.len() as f64 * 0.98);
        assert_eq!(Huffman.decompress(&comp, data.len()).unwrap(), data);
    }

    #[test]
    fn kraft_inequality_holds() {
        let mut rng = Rng::new(12);
        for _ in 0..50 {
            let mut counts = [0u64; 256];
            let n_syms = 1 + rng.below(256) as usize;
            for _ in 0..n_syms {
                counts[rng.below(256) as usize] += rng.pareto(1.0, 0.5) as u64 + 1;
            }
            let lens = code_lengths(&counts);
            let kraft: f64 = lens
                .iter()
                .filter(|&&l| l > 0)
                .map(|&l| 2f64.powi(-(l as i32)))
                .sum();
            assert!(kraft <= 1.0 + 1e-9, "kraft {kraft}");
            // and optimality-ish: no zero-count symbol got a code
            for s in 0..256 {
                assert_eq!(counts[s] == 0, lens[s] == 0, "sym {s}");
            }
        }
    }

    #[test]
    fn canonical_codes_are_prefix_free() {
        let mut counts = [0u64; 256];
        for s in 0..10 {
            counts[s] = (s as u64 + 1) * (s as u64 + 1);
        }
        let lens = code_lengths(&counts);
        let codes = canonical_codes(&lens);
        let used: Vec<(u32, u8)> =
            (0..256).filter(|&s| lens[s] > 0).map(|s| codes[s]).collect();
        for (i, &(ca, la)) in used.iter().enumerate() {
            for &(cb, lb) in used.iter().skip(i + 1) {
                let l = la.min(lb);
                assert_ne!(ca >> (la - l), cb >> (lb - l), "prefix collision");
            }
        }
    }

    #[test]
    fn corrupt_header_rejected() {
        let data = vec![1u8, 2, 3, 4, 5];
        let mut comp = Huffman.compress(&data);
        comp[0] = 255; // invalid length
        assert!(Huffman.decompress(&comp, data.len()).is_err());
        assert!(Huffman.decompress(&comp[..100], 5).is_err());
    }
}
