//! Frequent Pattern Compression (Alameldeen & Wood, 2004) — a word-level
//! significance-based baseline: each 32-bit word gets a 3-bit prefix
//! selecting one of eight patterns (zero, sign-extended narrow values,
//! halfword shapes, repeated bytes, or uncompressed).

use super::Codec;
use crate::util::bits::{BitReader, BitWriter};
use crate::{Error, Result};

/// FPC over 32-bit words (ragged tails stored raw with a 1-bit marker per
/// trailing byte group).
pub struct Fpc;

const P_ZERO: u64 = 0b000;
const P_S4: u64 = 0b001; // 4-bit sign-extended
const P_S8: u64 = 0b010; // 8-bit sign-extended
const P_S16: u64 = 0b011; // 16-bit sign-extended
const P_HI16: u64 = 0b100; // low half zero, high half 16 bits
const P_2X8: u64 = 0b101; // two halfwords, each 8-bit sign-extended
const P_REPB: u64 = 0b110; // four identical bytes
const P_RAW: u64 = 0b111;

#[inline]
fn sext_fits(v: u32, bits: u32) -> bool {
    let s = v as i32;
    let bias = 1i32 << (bits - 1);
    s >= -bias && s < bias
}

impl Fpc {
    fn encode_word(w: &mut BitWriter, v: u32) {
        if v == 0 {
            w.put(P_ZERO, 3);
        } else if sext_fits(v, 4) {
            w.put(P_S4, 3);
            w.put((v & 0xF) as u64, 4);
        } else if sext_fits(v, 8) {
            w.put(P_S8, 3);
            w.put((v & 0xFF) as u64, 8);
        } else if sext_fits(v, 16) {
            w.put(P_S16, 3);
            w.put((v & 0xFFFF) as u64, 16);
        } else if v & 0xFFFF == 0 {
            w.put(P_HI16, 3);
            w.put((v >> 16) as u64, 16);
        } else if {
            let lo = v as u16 as i16;
            let hi = (v >> 16) as u16 as i16;
            (-128..128).contains(&lo) && (-128..128).contains(&hi)
        } {
            w.put(P_2X8, 3);
            w.put((v & 0xFF) as u64, 8);
            w.put(((v >> 16) & 0xFF) as u64, 8);
        } else if v.to_le_bytes().windows(2).all(|p| p[0] == p[1]) {
            w.put(P_REPB, 3);
            w.put((v & 0xFF) as u64, 8);
        } else {
            w.put(P_RAW, 3);
            w.put(v as u64, 32);
        }
    }

    fn decode_word(r: &mut BitReader) -> Result<u32> {
        let corrupt = |m: &str| Error::Corrupt(format!("fpc: {m}"));
        let p = r.get(3).map_err(|_| corrupt("missing prefix"))?;
        Ok(match p {
            P_ZERO => 0,
            P_S4 => {
                let b = r.get(4).map_err(|_| corrupt("truncated s4"))? as u32;
                ((b << 28) as i32 >> 28) as u32
            }
            P_S8 => {
                let b = r.get(8).map_err(|_| corrupt("truncated s8"))? as u32;
                ((b << 24) as i32 >> 24) as u32
            }
            P_S16 => {
                let b = r.get(16).map_err(|_| corrupt("truncated s16"))? as u32;
                ((b << 16) as i32 >> 16) as u32
            }
            P_HI16 => {
                let b = r.get(16).map_err(|_| corrupt("truncated hi16"))? as u32;
                b << 16
            }
            P_2X8 => {
                let lo = r.get(8).map_err(|_| corrupt("truncated 2x8"))? as u32;
                let hi = r.get(8).map_err(|_| corrupt("truncated 2x8"))? as u32;
                let lo = ((lo << 24) as i32 >> 24) as u32 & 0xFFFF;
                let hi = ((hi << 24) as i32 >> 24) as u32 & 0xFFFF;
                lo | (hi << 16)
            }
            P_REPB => {
                let b = r.get(8).map_err(|_| corrupt("truncated repb"))? as u32;
                b | (b << 8) | (b << 16) | (b << 24)
            }
            P_RAW => r.get(32).map_err(|_| corrupt("truncated raw"))? as u32,
            _ => unreachable!(),
        })
    }
}

/// FPC as a block-granular codec for the unified [`BlockCodec`] layer:
/// the same word-level patterns, framed per block so the simulator, the
/// coordinator, and the container's parallel pipeline can drive it.
pub struct FpcBlock {
    /// Block size in bytes (a cache line).
    pub block_bytes: usize,
}

impl Default for FpcBlock {
    fn default() -> Self {
        FpcBlock { block_bytes: 64 }
    }
}

impl crate::codec::BlockCodec for FpcBlock {
    fn name(&self) -> &'static str {
        "fpc"
    }

    fn codec_id(&self) -> crate::codec::CodecId {
        crate::codec::CodecId::Fpc
    }

    fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    fn compress_block(&self, block: &[u8], w: &mut BitWriter) -> u32 {
        let start = w.bit_len();
        let words = block.len() / 4;
        for i in 0..words {
            let v = u32::from_le_bytes(block[i * 4..i * 4 + 4].try_into().unwrap());
            Fpc::encode_word(w, v);
        }
        w.put_bytes(&block[words * 4..]); // ragged tail raw
        (w.bit_len() - start) as u32
    }

    fn decompress_block(&self, r: &mut BitReader<'_>, out: &mut [u8]) -> Result<()> {
        let words = out.len() / 4;
        for i in 0..words {
            let v = Fpc::decode_word(r)?;
            out[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        let tail = words * 4;
        r.read_bytes(&mut out[tail..])
            .map_err(|_| Error::Corrupt("fpc: truncated tail".into()))?;
        Ok(())
    }

    fn config_bytes(&self) -> Vec<u8> {
        crate::codec::block_bytes_config(self.block_bytes)
    }
}

impl Codec for Fpc {
    fn name(&self) -> &'static str {
        "fpc"
    }

    fn compress(&self, data: &[u8]) -> Vec<u8> {
        let mut w = BitWriter::with_capacity(data.len() / 2 + 8);
        let words = data.len() / 4;
        for i in 0..words {
            let v = u32::from_le_bytes(data[i * 4..i * 4 + 4].try_into().unwrap());
            Self::encode_word(&mut w, v);
        }
        w.put_bytes(&data[words * 4..]); // ragged tail raw
        w.finish()
    }

    fn decompress(&self, comp: &[u8], original_len: usize) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(original_len);
        let mut r = BitReader::new(comp);
        let words = original_len / 4;
        for _ in 0..words {
            out.extend_from_slice(&Self::decode_word(&mut r)?.to_le_bytes());
        }
        while out.len() < original_len {
            out.push(r.get(8).map_err(|_| Error::Corrupt("fpc: truncated tail".into()))? as u8);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testsupport::roundtrip_battery;
    use crate::util::prng::Rng;

    #[test]
    fn battery() {
        roundtrip_battery(&Fpc);
    }

    #[test]
    fn patterns_roundtrip_exhaustive_edges() {
        let cases: Vec<u32> = vec![
            0,
            1,
            7,
            8,
            0xFFFF_FFFF, // -1
            0xFFFF_FFF8, // -8
            127,
            128,
            0xFFFF_FF80,
            32767,
            32768,
            0xFFFF_8000,
            0x7FFF_0000,
            0x1234_0000,
            0x0042_0017, // 2x8
            0xABAB_ABAB, // repeated bytes
            0xDEAD_BEEF, // raw
        ];
        for &v in &cases {
            let mut w = BitWriter::new();
            Fpc::encode_word(&mut w, v);
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            assert_eq!(Fpc::decode_word(&mut r).unwrap(), v, "v={v:#x}");
        }
    }

    #[test]
    fn small_values_shrink() {
        let mut data = Vec::new();
        for i in 0i32..1024 {
            data.extend_from_slice(&(i % 5).to_le_bytes());
        }
        let r = crate::baselines::ratio_of(&Fpc, &data);
        assert!(r > 3.0, "ratio {r}");
    }

    #[test]
    fn fuzz_roundtrip() {
        let mut rng = Rng::new(17);
        for _ in 0..200 {
            let len = rng.below(1024) as usize;
            let mut data = vec![0u8; len];
            rng.fill_bytes(&mut data);
            let comp = Fpc.compress(&data);
            assert_eq!(Fpc.decompress(&comp, len).unwrap(), data);
        }
    }

    #[test]
    fn truncation_detected() {
        let data = vec![0xDE; 256];
        let comp = Fpc.compress(&data);
        assert!(Fpc.decompress(&comp[..comp.len() / 4], 256).is_err());
    }
}
