//! LZSS — the dictionary ("LZ compression") baseline from the paper's
//! §1.1. Byte-oriented sliding window with a hash-chain matcher: output is
//! a bitstream of `0 + literal byte` or `1 + offset + length` tokens.

use super::Codec;
use crate::util::bits::{BitReader, BitWriter};
use crate::{Error, Result};

/// LZSS with a 32 KiB window (15-bit offsets) and 4..=258 byte matches.
pub struct Lzss {
    /// log2 of the window size (offset bits).
    pub window_bits: u32,
}

impl Default for Lzss {
    fn default() -> Self {
        Lzss { window_bits: 15 }
    }
}

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = 258; // len field stores len - MIN_MATCH in 8 bits
const HASH_BITS: u32 = 15;

#[inline]
fn hash4(data: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes(data[i..i + 4].try_into().unwrap());
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

impl Codec for Lzss {
    fn name(&self) -> &'static str {
        "lzss"
    }

    fn compress(&self, data: &[u8]) -> Vec<u8> {
        let window = 1usize << self.window_bits;
        let mut w = BitWriter::with_capacity(data.len() + data.len() / 8 + 16);
        let mut head = vec![usize::MAX; 1 << HASH_BITS];
        let mut prev = vec![usize::MAX; data.len()];
        let mut i = 0;
        while i < data.len() {
            let mut best_len = 0usize;
            let mut best_off = 0usize;
            if i + MIN_MATCH <= data.len() {
                let mut cand = head[hash4(data, i)];
                let mut chain = 0;
                while cand != usize::MAX && i - cand <= window && chain < 64 {
                    let max = (data.len() - i).min(MAX_MATCH);
                    let mut l = 0;
                    while l < max && data[cand + l] == data[i + l] {
                        l += 1;
                    }
                    if l > best_len {
                        best_len = l;
                        best_off = i - cand;
                        if l >= max {
                            break;
                        }
                    }
                    cand = prev[cand];
                    chain += 1;
                }
            }
            if best_len >= MIN_MATCH {
                w.put_bit(true);
                w.put((best_off - 1) as u64, self.window_bits);
                w.put((best_len - MIN_MATCH) as u64, 8);
                // insert hash entries for covered positions
                let end = i + best_len;
                while i < end {
                    if i + MIN_MATCH <= data.len() {
                        let h = hash4(data, i);
                        prev[i] = head[h];
                        head[h] = i;
                    }
                    i += 1;
                }
            } else {
                w.put_bit(false);
                w.put(data[i] as u64, 8);
                if i + MIN_MATCH <= data.len() {
                    let h = hash4(data, i);
                    prev[i] = head[h];
                    head[h] = i;
                }
                i += 1;
            }
        }
        w.finish()
    }

    fn decompress(&self, comp: &[u8], original_len: usize) -> Result<Vec<u8>> {
        let corrupt = |m: &str| Error::Corrupt(format!("lzss: {m}"));
        let mut out: Vec<u8> = Vec::with_capacity(original_len);
        let mut r = BitReader::new(comp);
        while out.len() < original_len {
            let is_match = r.get_bit().map_err(|_| corrupt("truncated token"))?;
            if is_match {
                let off = r.get(self.window_bits).map_err(|_| corrupt("truncated offset"))? as usize + 1;
                let len =
                    r.get(8).map_err(|_| corrupt("truncated length"))? as usize + MIN_MATCH;
                if off > out.len() {
                    return Err(corrupt("offset beyond history"));
                }
                if out.len() + len > original_len {
                    return Err(corrupt("match overruns output"));
                }
                let start = out.len() - off;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            } else {
                out.push(r.get(8).map_err(|_| corrupt("truncated literal"))? as u8);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testsupport::roundtrip_battery;
    use crate::util::prng::Rng;

    #[test]
    fn battery() {
        roundtrip_battery(&Lzss::default());
    }

    #[test]
    fn repetitive_text_compresses_well() {
        let data: Vec<u8> = b"the quick brown fox jumps over the lazy dog. "
            .iter()
            .cycle()
            .take(1 << 16)
            .copied()
            .collect();
        let r = crate::baselines::ratio_of(&Lzss::default(), &data);
        assert!(r > 8.0, "ratio {r}");
    }

    #[test]
    fn overlapping_match_roundtrips() {
        // run-length via self-overlapping match (offset 1, long length)
        let data = vec![7u8; 1000];
        let lz = Lzss::default();
        let comp = lz.compress(&data);
        assert!(comp.len() < 40, "compressed {}", comp.len());
        assert_eq!(lz.decompress(&comp, 1000).unwrap(), data);
    }

    #[test]
    fn incompressible_expansion_bounded() {
        let mut rng = Rng::new(8);
        let mut data = vec![0u8; 1 << 14];
        rng.fill_bytes(&mut data);
        let lz = Lzss::default();
        let comp = lz.compress(&data);
        assert!((comp.len() as f64) < data.len() as f64 * 1.14);
        assert_eq!(lz.decompress(&comp, data.len()).unwrap(), data);
    }

    #[test]
    fn fuzz_structured_roundtrip() {
        let mut rng = Rng::new(9);
        let lz = Lzss::default();
        for _ in 0..60 {
            let len = rng.below(4096) as usize;
            let mut data = Vec::with_capacity(len);
            while data.len() < len {
                if rng.chance(0.3) || data.is_empty() {
                    data.push(rng.next_u32() as u8);
                } else {
                    // copy an earlier slice (creates matches)
                    let start = rng.below(data.len() as u64) as usize;
                    let n = (rng.below(40) as usize + 1).min(data.len() - start).min(len - data.len());
                    let copied: Vec<u8> = data[start..start + n].to_vec();
                    data.extend(copied);
                }
            }
            let comp = lz.compress(&data);
            assert_eq!(lz.decompress(&comp, data.len()).unwrap(), data);
        }
    }

    #[test]
    fn corrupt_offset_detected() {
        // handcraft: match token with offset beyond history
        let mut w = BitWriter::new();
        w.put_bit(true);
        w.put(100, 15); // offset 101 with empty history
        w.put(0, 8);
        let bytes = w.finish();
        assert!(Lzss::default().decompress(&bytes, 10).is_err());
    }
}
