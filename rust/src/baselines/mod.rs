//! Baseline compressors the paper discusses (§1.1) and compares against:
//! BDI (the algorithm GBDI extends), FPC, LZ (LZSS), Huffman coding, and
//! gzip/zstd as the general-purpose comparators. All are lossless and
//! roundtrip-tested; all implement the whole-image [`Codec`] trait so the
//! benches can sweep them uniformly, and the block-granular ones (BDI,
//! FPC) additionally implement [`crate::codec::BlockCodec`] so the memory
//! simulator, the coordinator, and the container's parallel pipeline can
//! drive them interchangeably with GBDI.

pub mod bdi;
pub mod external;
pub mod fpc;
pub mod huffman;
pub mod lzss;

use crate::Result;

/// A whole-image lossless codec.
pub trait Codec: Send + Sync {
    /// Short identifier used in reports (e.g. `"bdi"`).
    fn name(&self) -> &'static str;
    /// Compress `data` into a self-contained byte stream.
    fn compress(&self, data: &[u8]) -> Vec<u8>;
    /// Reconstruct the original `original_len` bytes.
    fn decompress(&self, comp: &[u8], original_len: usize) -> Result<Vec<u8>>;
}

/// Compression ratio (original/compressed) of a codec on `data`.
pub fn ratio_of(codec: &dyn Codec, data: &[u8]) -> f64 {
    if data.is_empty() {
        return 1.0;
    }
    let comp = codec.compress(data);
    data.len() as f64 / comp.len().max(1) as f64
}

/// GBDI wrapped as a self-contained [`Codec`]: runs background analysis on
/// the image itself, then embeds the serialized table, framing, and payload
/// in one buffer. This is what the baseline benches sweep so every codec
/// pays for its own metadata.
pub struct GbdiWholeImage {
    /// Codec configuration for analysis + encoding.
    pub config: crate::gbdi::GbdiConfig,
}

impl Default for GbdiWholeImage {
    fn default() -> Self {
        GbdiWholeImage { config: crate::gbdi::GbdiConfig::default() }
    }
}

impl GbdiWholeImage {
    /// Original length recorded in a compressed container (so the CLI can
    /// decompress without out-of-band metadata). Header-only: does not
    /// parse the block index or copy the payload.
    pub fn container_len(comp: &[u8]) -> Result<usize> {
        crate::container::Container::original_len_of(comp)
    }
}

impl Codec for GbdiWholeImage {
    fn name(&self) -> &'static str {
        "gbdi"
    }

    fn compress(&self, data: &[u8]) -> Vec<u8> {
        let table = crate::gbdi::analyze::analyze_image(data, &self.config);
        let codec = crate::gbdi::GbdiCodec::new(table, self.config.clone());
        // One unified frame for every block codec (u32-varint per-block bit
        // lengths — the old ad-hoc u16 framing truncated oversized blocks).
        crate::container::compress(&codec, data).to_bytes()
    }

    fn decompress(&self, comp: &[u8], original_len: usize) -> Result<Vec<u8>> {
        let c = crate::container::Container::from_bytes(comp)?;
        if c.original_len != original_len {
            return Err(crate::Error::Corrupt(format!(
                "length mismatch: container says {}, caller says {original_len}",
                c.original_len
            )));
        }
        c.decompress()
    }
}

/// All codecs the E3 baseline table sweeps, in report order.
pub fn all_codecs() -> Vec<Box<dyn Codec>> {
    vec![
        Box::new(GbdiWholeImage::default()),
        Box::new(bdi::Bdi::default()),
        Box::new(fpc::Fpc),
        Box::new(lzss::Lzss::default()),
        Box::new(huffman::Huffman),
        Box::new(external::Gzip::default()),
        Box::new(external::Zstd::default()),
    ]
}

#[cfg(test)]
pub(crate) mod testsupport {
    use super::*;
    use crate::util::prng::Rng;

    /// Shared roundtrip battery every codec must pass.
    pub(crate) fn roundtrip_battery(codec: &dyn Codec) {
        let mut rng = Rng::new(0xBA77E12);
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            vec![0u8; 1],
            vec![0u8; 4096],
            vec![0xAB; 777],
            (0..=255u8).cycle().take(2048).collect(),
            {
                let mut v = vec![0u8; 8192];
                rng.fill_bytes(&mut v);
                v
            },
            {
                // clustered words
                let mut v = Vec::new();
                for _ in 0..1024 {
                    let base: u32 = if rng.chance(0.5) { 0x1000_0000 } else { 0x7FFF_0000 };
                    v.extend_from_slice(&(base + rng.below(256) as u32).to_le_bytes());
                }
                v
            },
            vec![1, 2, 3], // ragged
        ];
        for (i, case) in cases.iter().enumerate() {
            let comp = codec.compress(case);
            let back = codec
                .decompress(&comp, case.len())
                .unwrap_or_else(|e| panic!("{}: case {i} failed to decompress: {e}", codec.name()));
            assert_eq!(&back, case, "{}: case {i} roundtrip", codec.name());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testsupport::roundtrip_battery;
    use super::*;

    #[test]
    fn gbdi_whole_image_roundtrips() {
        roundtrip_battery(&GbdiWholeImage::default());
    }

    #[test]
    fn gbdi_whole_image_detects_corruption() {
        let c = GbdiWholeImage::default();
        let data = vec![7u8; 4096];
        let comp = c.compress(&data);
        assert!(c.decompress(&comp[..10], 4096).is_err());
        assert!(c.decompress(&comp, 4095).is_err());
    }

    #[test]
    fn all_codecs_present() {
        let names: Vec<&str> = all_codecs().iter().map(|c| c.name()).collect();
        assert_eq!(names, vec!["gbdi", "bdi", "fpc", "lzss", "huffman", "gzip", "zstd"]);
    }

    #[test]
    fn ratio_of_compressible_data() {
        let zeros = vec![0u8; 1 << 16];
        for codec in all_codecs() {
            let r = ratio_of(codec.as_ref(), &zeros);
            assert!(r > 3.0, "{} ratio on zeros = {r}", codec.name());
        }
    }
}
