//! SPEC CPU 2017 workload models: `605.mcf_s`, `600.perlbench_s`,
//! `620.omnetpp_s`, `631.deepsjeng_s` — the paper's C-workload set.
//!
//! Region mixtures follow the applications' published memory behaviour:
//! mcf is a network-simplex solver over a pointer-linked arc/node graph;
//! perlbench is an interpreter dominated by string/SV structures; omnetpp
//! is a discrete-event simulator (event objects, timestamps, queues);
//! deepsjeng is a chess engine (bitboards + a huge transposition table).

use super::regions::*;
use super::{workload_rng, Group, Workload};

/// `605.mcf_s`: network simplex. Memory is arrays of arc/node structs:
/// 64-bit pointers into two arenas, 32-bit costs/flows (small magnitudes),
/// and flag words. Highly base-clusterable (few arenas, narrow deltas).
pub struct Mcf;

impl Workload for Mcf {
    fn name(&self) -> &'static str {
        "mcf"
    }
    fn group(&self) -> Group {
        Group::SpecCpu
    }
    fn paper_dump(&self) -> &'static str {
        "605.mcf_s_5.dump"
    }
    fn description(&self) -> &'static str {
        "network-simplex arc/node graph: pointer arenas + small int costs"
    }
    fn generate(&self, bytes: usize, seed: u64) -> Vec<u8> {
        let mut rng = workload_rng(self.name(), seed);
        // arenas sized to real mcf_s resident sets: allocation locality
        // keeps the hot node/arc arrays within a few MiB
        let nodes = PointerArena { base: 0x7F3A_4000_0000, span: 1 << 20, align: 64 };
        // distinct mmap region, > 2^31 away from the node arena
        let arcs = PointerArena { base: 0x7FC2_2000_0000, span: 1 << 21, align: 32 };
        Composer::new()
            // arc structs (64 B): pointers into TWO arenas + scalar fields
            // in the same cache block — the exact intra-block population
            // mix per-block-base BDI cannot capture but global bases can
            .part(4.0, move |p, r| {
                for arc in p.chunks_mut(64) {
                    if arc.len() < 64 {
                        fill_small_ints(arc, 10_000, 0.25, r);
                        continue;
                    }
                    arc[0..8].copy_from_slice(&nodes.ptr(r).to_le_bytes()); // tail
                    arc[8..16].copy_from_slice(&nodes.ptr(r).to_le_bytes()); // head
                    arc[16..24].copy_from_slice(&arcs.ptr(r).to_le_bytes()); // nextout
                    arc[24..32].copy_from_slice(&arcs.ptr(r).to_le_bytes()); // nextin
                    fill_small_ints(&mut arc[32..48], 10_000, 0.25, r); // cost/flow
                    fill_small_ints(&mut arc[48..64], 100, 0.5, r); // ident/flags
                }
            })
            // cost / flow / potential arrays
            .part(2.0, |p, r| fill_small_ints(p, 10_000, 0.25, r))
            // untouched allocator slack
            .part(2.0, |p, _| p.fill(0))
            // misc state
            .part(0.4, |p, r| r.fill_bytes(p))
            .generate(bytes, &mut rng)
    }
}

/// `600.perlbench_s`: the perl interpreter. String buffers, SV/HV
/// structures (pointer + small-flag pairs), op-tree pointers.
pub struct Perlbench;

impl Workload for Perlbench {
    fn name(&self) -> &'static str {
        "perlbench"
    }
    fn group(&self) -> Group {
        Group::SpecCpu
    }
    fn paper_dump(&self) -> &'static str {
        "600.perlbench_s_5.dump"
    }
    fn description(&self) -> &'static str {
        "interpreter heap: SV structs, string buffers, op-tree pointers"
    }
    fn generate(&self, bytes: usize, seed: u64) -> Vec<u8> {
        let mut rng = workload_rng(self.name(), seed);
        let sv_arena = PointerArena { base: 0x5555_6000_0000, span: 1 << 21, align: 16 };
        let str_arena = PointerArena { base: 0x7F88_4000_0000, span: 1 << 21, align: 8 };
        Composer::new()
            // string/pad buffers
            .part(2.5, |p, r| fill_text(p, r))
            // SV bodies: pointer + refcount/flags interleave
            .part(2.5, move |p, r| {
                for s in p.chunks_mut(16) {
                    let ptr = sv_arena.ptr(r).to_le_bytes();
                    let n = s.len().min(8);
                    s[..n].copy_from_slice(&ptr[..n]);
                    if s.len() >= 16 {
                        let refcnt = (1 + r.zipf(64, 1.3)) as u32;
                        let flags = [0x0400u32, 0x2804, 0x0801, 0x1000][r.below(4) as usize];
                        s[8..12].copy_from_slice(&refcnt.to_le_bytes());
                        s[12..16].copy_from_slice(&flags.to_le_bytes());
                    }
                }
            })
            // op-tree / hash buckets
            .part(1.5, move |p, r| fill_pointers(p, &str_arena, r))
            .part(1.5, |p, _| p.fill(0))
            .part(0.4, |p, r| r.fill_bytes(p))
            .generate(bytes, &mut rng)
    }
}

/// `620.omnetpp_s`: discrete-event network simulation. Event objects with
/// vtable pointers, monotone timestamps, message queues.
pub struct Omnetpp;

impl Workload for Omnetpp {
    fn name(&self) -> &'static str {
        "omnetpp"
    }
    fn group(&self) -> Group {
        Group::SpecCpu
    }
    fn paper_dump(&self) -> &'static str {
        "620.omnetpp_s_5.dump"
    }
    fn description(&self) -> &'static str {
        "discrete-event sim: vtable ptrs, timestamps, message queues"
    }
    fn generate(&self, bytes: usize, seed: u64) -> Vec<u8> {
        let mut rng = workload_rng(self.name(), seed);
        let vtables = PointerArena { base: 0x5555_5560_0000, span: 1 << 14, align: 8 };
        let heap = PointerArena { base: 0x7F10_0000_0000, span: 1 << 21, align: 32 };
        let t0 = rng.below(1 << 40);
        Composer::new()
            // event objects: vptr + heap links + small fields
            .part(3.0, move |p, r| {
                for obj in p.chunks_mut(64) {
                    let n = obj.len();
                    if n < 64 {
                        fill_small_ints(obj, 100, 0.3, r);
                        continue;
                    }
                    obj[0..8].copy_from_slice(&vtables.ptr(r).to_le_bytes());
                    obj[8..16].copy_from_slice(&heap.ptr(r).to_le_bytes());
                    obj[16..24].copy_from_slice(&heap.ptr(r).to_le_bytes());
                    fill_small_ints(&mut obj[24..40], 1000, 0.4, r);
                    // simtime (ns-scale fixed point, clustered magnitudes)
                    let t = t0 + r.below(1 << 18);
                    obj[40..48].copy_from_slice(&t.to_le_bytes());
                    fill_small_ints(&mut obj[48..64], 64, 0.5, r);
                }
            })
            // future-event-set timestamps
            .part(1.5, move |p, r| fill_counters(p, t0, 64, r))
            .part(1.2, |p, _| p.fill(0))
            .part(0.6, |p, r| r.fill_bytes(p))
            .generate(bytes, &mut rng)
    }
}

/// `631.deepsjeng_s`: chess engine. Transposition table (mostly-empty
/// hash entries), bitboards, killer/history heuristic arrays. The least
/// compressible of the paper's set.
pub struct Deepsjeng;

impl Workload for Deepsjeng {
    fn name(&self) -> &'static str {
        "deepsjeng"
    }
    fn group(&self) -> Group {
        Group::SpecCpu
    }
    fn paper_dump(&self) -> &'static str {
        "631.deepsjeng_s_5.dump"
    }
    fn description(&self) -> &'static str {
        "chess engine: transposition table, bitboards, history arrays"
    }
    fn generate(&self, bytes: usize, seed: u64) -> Vec<u8> {
        let mut rng = workload_rng(self.name(), seed);
        let heap = PointerArena { base: 0x7F77_0000_0000, span: 1 << 26, align: 16 };
        Composer::new()
            // transposition table dominates the footprint; sjeng keeps it
            // hot (high fill), and keys/payloads are high-entropy hashes
            .part(5.0, move |p, r| fill_hash_table(p, 0.8, &heap, r))
            .part(2.5, |p, r| fill_bitboards(p, r))
            // history / killer tables: small bounded counters
            .part(1.2, |p, r| fill_small_ints(p, 512, 0.35, r))
            .part(0.5, |p, _| p.fill(0))
            .generate(bytes, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{ratio_of, GbdiWholeImage};

    #[test]
    fn mcf_is_gbdi_friendly() {
        let img = Mcf.generate(1 << 20, 1);
        let r = ratio_of(&GbdiWholeImage::default(), &img);
        assert!(r > 1.2, "mcf gbdi ratio {r}");
    }

    #[test]
    fn deepsjeng_is_least_compressible_spec() {
        let g = GbdiWholeImage::default();
        let r_deep = ratio_of(&g, &Deepsjeng.generate(1 << 20, 1));
        let r_mcf = ratio_of(&g, &Mcf.generate(1 << 20, 1));
        assert!(r_deep < r_mcf, "deepsjeng {r_deep} vs mcf {r_mcf}");
        assert!(r_deep > 1.0, "still above 1.0: {r_deep}");
    }

    #[test]
    fn perlbench_text_regions_visible() {
        let img = Perlbench.generate(1 << 18, 2);
        // some pages should be pure ASCII text
        let ascii_pages = img
            .chunks(4096)
            .filter(|p| p.iter().all(|&b| b.is_ascii_lowercase() || b == b' '))
            .count();
        assert!(ascii_pages > 5, "ascii pages {ascii_pages}");
    }

    #[test]
    fn omnetpp_timestamps_monotone_within_counter_pages() {
        let img = Omnetpp.generate(1 << 18, 3);
        assert_eq!(img.len(), 1 << 18);
    }
}
