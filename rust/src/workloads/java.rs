//! Java workload models: `TriangleCount`, `SVM`, `MatrixFactorization`
//! (the paper's Java dump set), built on a shared JVM heap layout model.
//!
//! HotSpot heap memory is *more* GBDI-friendly than C heaps — the paper
//! measures 1.55× (Java) vs 1.4× (C) — because every object carries a
//! regular 12-byte header (mark word + compressed klass pointer from a
//! tiny metaspace set) and references are 32-bit compressed oops into one
//! contiguous heap: exactly the few-global-bases population GBDI wants.

use super::regions::*;
use super::{workload_rng, Group, Workload};
use crate::util::prng::Rng;

/// Shared HotSpot-style heap modelling: 12-byte headers, compressed oops.
pub struct JvmHeap {
    /// Compressed-oop heap base (oops are 32-bit offsets scaled by 8).
    pub heap_words: u64,
    /// Number of distinct klass ids in play.
    pub klasses: u64,
}

impl Default for JvmHeap {
    fn default() -> Self {
        // young-gen/TLAB locality: live references concentrate in a
        // ~512 MiB window of the heap (2^26 words); ~200 hot classes
        JvmHeap { heap_words: 1 << 26, klasses: 200 }
    }
}

impl JvmHeap {
    /// A compressed oop (32-bit scaled reference), Zipf-hot like real
    /// allocation sites.
    pub fn oop(&self, rng: &mut Rng) -> u32 {
        rng.zipf(self.heap_words, 1.0) as u32
    }

    /// Write a 12-byte object header at `out[0..12]`: mark word (unlocked,
    /// occasional identity hash) + compressed klass pointer.
    pub fn header(&self, out: &mut [u8], rng: &mut Rng) {
        let mark: u64 = if rng.chance(0.15) {
            // identity hash installed: hash<<8 | unlocked(0b001)
            ((rng.below(1 << 31)) << 8) | 0b001
        } else {
            0b001 // clean unlocked mark
        };
        let klass: u32 = 0x0080_0000 + (rng.zipf(self.klasses, 1.1) as u32) * 0x68;
        out[0..8].copy_from_slice(&mark.to_le_bytes());
        out[8..12].copy_from_slice(&klass.to_le_bytes());
    }

    /// Fill a page with reference-heavy objects (e.g. HashMap$Node:
    /// header, hash, key/value/next oops, pad to 32).
    pub fn fill_node_objects(&self, page: &mut [u8], rng: &mut Rng) {
        for obj in page.chunks_mut(32) {
            if obj.len() < 32 {
                obj.fill(0);
                continue;
            }
            self.header(obj, rng);
            let hash = rng.next_u32();
            obj[12..16].copy_from_slice(&hash.to_le_bytes());
            obj[16..20].copy_from_slice(&self.oop(rng).to_le_bytes());
            obj[20..24].copy_from_slice(&self.oop(rng).to_le_bytes());
            obj[24..28].copy_from_slice(&self.oop(rng).to_le_bytes());
            obj[28..32].fill(0); // alignment pad
        }
    }

    /// Fill a page as an `int[]` arena: array headers then small ints.
    pub fn fill_int_arrays(&self, page: &mut [u8], mag: i64, rng: &mut Rng) {
        let mut i = 0;
        while i + 16 <= page.len() {
            self.header(&mut page[i..i + 12], rng);
            let run = 16 + 8 * rng.below(28) as usize; // payload bytes
            let len_field = (run / 4) as u32;
            page[i + 12..i + 16].copy_from_slice(&len_field.to_le_bytes());
            i += 16;
            let end = (i + run).min(page.len());
            fill_small_ints(&mut page[i..end], mag, 0.1, rng);
            i = end;
        }
        if i < page.len() {
            page[i..].fill(0);
        }
    }

    /// Fill a page as reference arrays (`Object[]`): array headers then
    /// packed compressed oops — the densest GBDI-friendly JVM population
    /// (many clusterable 32-bit values per block, hostile to per-block
    /// bases because oops scatter across the heap within one array).
    pub fn fill_oop_arrays(&self, page: &mut [u8], rng: &mut Rng) {
        let mut i = 0;
        while i + 16 <= page.len() {
            self.header(&mut page[i..i + 12], rng);
            let run = 16 + 4 * rng.below(60) as usize;
            let len_field = (run / 4) as u32;
            page[i + 12..i + 16].copy_from_slice(&len_field.to_le_bytes());
            i += 16;
            let end = (i + run).min(page.len());
            for c in page[i..end].chunks_mut(4) {
                let oop = self.oop(rng).to_le_bytes();
                let n = c.len();
                c.copy_from_slice(&oop[..n]);
            }
            i = end;
        }
        if i < page.len() {
            page[i..].fill(0);
        }
    }

    /// Fill a page as a GC card table: one byte per 512-byte heap card,
    /// almost all clean (0) with sparse dirty marks.
    pub fn fill_card_table(&self, page: &mut [u8], rng: &mut Rng) {
        page.fill(0);
        let dirty = page.len() / 64;
        for _ in 0..dirty {
            let i = rng.below(page.len() as u64) as usize;
            page[i] = 1;
        }
    }

    /// Fill a page as a `double[]` arena (values ~N(mean, sd)).
    pub fn fill_double_arrays(&self, page: &mut [u8], mean: f64, sd: f64, rng: &mut Rng) {
        let mut i = 0;
        while i + 16 <= page.len() {
            self.header(&mut page[i..i + 12], rng);
            let run = 32 + 8 * rng.below(60) as usize;
            let len_field = (run / 8) as u32;
            page[i + 12..i + 16].copy_from_slice(&len_field.to_le_bytes());
            i += 16;
            let end = (i + run).min(page.len());
            fill_f64(&mut page[i..end], mean, sd, rng);
            i = end;
        }
        if i < page.len() {
            page[i..].fill(0);
        }
    }
}

/// `TriangleCount`: graph analytics. Adjacency `int[]`s (vertex ids),
/// HashMap nodes, boxed Integers.
pub struct TriangleCount;

impl Workload for TriangleCount {
    fn name(&self) -> &'static str {
        "triangle_count"
    }
    fn group(&self) -> Group {
        Group::Java
    }
    fn paper_dump(&self) -> &'static str {
        "TriangleCount_3.dump"
    }
    fn description(&self) -> &'static str {
        "JVM graph analytics: adjacency int[] + HashMap nodes + boxed ints"
    }
    fn generate(&self, bytes: usize, seed: u64) -> Vec<u8> {
        let mut rng = workload_rng(self.name(), seed);
        let h = JvmHeap::default();
        let h2 = JvmHeap::default();
        let h3 = JvmHeap::default();
        let h4 = JvmHeap::default();
        let h5 = JvmHeap::default();
        Composer::new()
            .part(3.0, move |p, r| h.fill_int_arrays(p, 2_000_000, r)) // vertex ids
            .part(2.0, move |p, r| h2.fill_node_objects(p, r))
            .part(2.0, move |p, r| h4.fill_oop_arrays(p, r)) // adjacency Object[]
            .part(0.6, move |p, r| h5.fill_card_table(p, r))
            // boxed Integer cache-misses: header + small value + pad
            .part(1.5, move |p, r| {
                for obj in p.chunks_mut(16) {
                    if obj.len() < 16 {
                        obj.fill(0);
                        continue;
                    }
                    h3.header(obj, r);
                    let v = r.range_i64(-1000, 10_000) as i32;
                    obj[12..16].copy_from_slice(&v.to_le_bytes());
                }
            })
            // TLAB / survivor slack
            .part(1.5, |p, _| p.fill(0))
            .generate(bytes, &mut rng)
    }
}

/// `SVM`: support-vector machine training. Feature `double[]`s with
/// normalized values, alpha vectors, kernel cache rows.
pub struct Svm;

impl Workload for Svm {
    fn name(&self) -> &'static str {
        "svm"
    }
    fn group(&self) -> Group {
        Group::Java
    }
    fn paper_dump(&self) -> &'static str {
        "SVM_3.dump"
    }
    fn description(&self) -> &'static str {
        "JVM SVM training: normalized double[] features + kernel cache"
    }
    fn generate(&self, bytes: usize, seed: u64) -> Vec<u8> {
        let mut rng = workload_rng(self.name(), seed);
        let h = JvmHeap::default();
        let h2 = JvmHeap::default();
        let h3 = JvmHeap::default();
        Composer::new()
            // feature vectors: tf-idf style quantized doubles (most real
            // SVM datasets are categorical/one-hot/bucketized)
            .part(3.0, |p, r| fill_f64_quantized(p, 256, 1.0, r))
            // alpha / gradient vectors: sparse (few support vectors)
            .part(2.0, |p, r| fill_sparse_f64(p, 0.08, 1.0, 0.5, r))
            // kernel cache rows: continuous doubles (incompressible tail)
            .part(1.0, move |p, r| h.fill_double_arrays(p, 0.0, 0.05, r))
            // sparse feature indices
            .part(1.5, move |p, r| h2.fill_int_arrays(p, 50_000, r))
            .part(0.5, move |p, r| h3.fill_card_table(p, r))
            .part(1.5, |p, _| p.fill(0))
            .generate(bytes, &mut rng)
    }
}

/// `MatrixFactorization`: ALS-style factorization. Large latent-factor
/// `double[]`s, rating triples (user, item, rating), index maps.
pub struct MatrixFactorization;

impl Workload for MatrixFactorization {
    fn name(&self) -> &'static str {
        "matrix_factorization"
    }
    fn group(&self) -> Group {
        Group::Java
    }
    fn paper_dump(&self) -> &'static str {
        "MatrixFactorization_4.dump"
    }
    fn description(&self) -> &'static str {
        "JVM ALS: latent-factor double[] + rating triples + index maps"
    }
    fn generate(&self, bytes: usize, seed: u64) -> Vec<u8> {
        let mut rng = workload_rng(self.name(), seed);
        let h = JvmHeap::default();
        let h2 = JvmHeap::default();
        let h3 = JvmHeap::default();
        let h4 = JvmHeap::default();
        Composer::new()
            // ratings matrix: half-star levels stored as doubles
            .part(3.0, |p, r| fill_f64_quantized(p, 10, 5.0, r))
            // latent factors: continuous small doubles (honest tail)
            .part(1.4, move |p, r| h.fill_double_arrays(p, 0.0, 0.1, r))
            // rating triples: user id, item id, rating*10 (all small ints)
            .part(2.0, move |p, r| h2.fill_int_arrays(p, 480_000, r))
            .part(1.2, move |p, r| h3.fill_node_objects(p, r))
            .part(1.0, move |p, r| h4.fill_oop_arrays(p, r))
            .part(1.5, |p, _| p.fill(0))
            .generate(bytes, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{ratio_of, GbdiWholeImage};

    #[test]
    fn headers_have_unlocked_mark() {
        let h = JvmHeap::default();
        let mut rng = Rng::new(1);
        let mut buf = [0u8; 12];
        for _ in 0..100 {
            h.header(&mut buf, &mut rng);
            let mark = u64::from_le_bytes(buf[0..8].try_into().unwrap());
            assert_eq!(mark & 0b111, 0b001, "unlocked biasable mark");
            let klass = u32::from_le_bytes(buf[8..12].try_into().unwrap());
            assert!(klass >= 0x0080_0000 && klass < 0x0080_0000 + 200 * 0x68);
        }
    }

    #[test]
    fn java_workloads_beat_typical_c_ratio() {
        // the paper's core finding: Java group compresses better than C
        let g = GbdiWholeImage::default();
        let java_avg: f64 = [
            ratio_of(&g, &TriangleCount.generate(1 << 20, 3)),
            ratio_of(&g, &Svm.generate(1 << 20, 3)),
            ratio_of(&g, &MatrixFactorization.generate(1 << 20, 3)),
        ]
        .iter()
        .sum::<f64>()
            / 3.0;
        assert!(java_avg > 1.3, "java avg {java_avg}");
    }

    #[test]
    fn int_array_pages_parse_back() {
        let h = JvmHeap::default();
        let mut rng = Rng::new(2);
        let mut page = vec![0u8; 4096];
        h.fill_int_arrays(&mut page, 1000, &mut rng);
        // spot-check: first object header at 0, length field sane
        let len = u32::from_le_bytes(page[12..16].try_into().unwrap());
        assert!(len >= 4 && len <= 60, "len {len}");
    }

    #[test]
    fn double_arrays_have_clustered_exponents() {
        let h = JvmHeap::default();
        let mut rng = Rng::new(3);
        let mut page = vec![0u8; 1 << 16];
        h.fill_double_arrays(&mut page, 0.0, 0.1, &mut rng);
        assert_eq!(page.len(), 1 << 16);
    }
}
