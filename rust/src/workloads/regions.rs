//! Memory-region synthesizers: the building blocks the nine workload
//! models compose. Each region kind reproduces a value population seen in
//! real process memory (pointer arenas, small-integer fields, FP arrays,
//! text, hash tables, zero pages), because GBDI's compression ratio is a
//! function of exactly that population.

use crate::util::prng::Rng;

/// A distribution of 64-bit pointers into a contiguous arena: high bits
/// shared, low bits spread over `span` with `align` granularity. Written
/// little-endian, so the *upper* 32-bit word of every pointer clusters
/// tightly — the effect GBDI's global bases exploit across blocks.
#[derive(Debug, Clone, Copy)]
pub struct PointerArena {
    /// Arena base address (e.g. a mmap'd heap at 0x7f3a_0000_0000).
    pub base: u64,
    /// Arena extent in bytes.
    pub span: u64,
    /// Pointer alignment (8 or 16 typically).
    pub align: u64,
}

impl PointerArena {
    /// One pointer into the arena (Zipf-hot: allocation sites cluster).
    pub fn ptr(&self, rng: &mut Rng) -> u64 {
        let slots = (self.span / self.align).max(1);
        let slot = rng.zipf(slots, 0.8);
        self.base + slot * self.align
    }
}

/// Fill `out` with little-endian u64 pointers from the arena.
pub fn fill_pointers(out: &mut [u8], arena: &PointerArena, rng: &mut Rng) {
    for c in out.chunks_mut(8) {
        let p = arena.ptr(rng);
        let b = p.to_le_bytes();
        let n = c.len();
        c.copy_from_slice(&b[..n]);
    }
}

/// Fill with i32 values that are mostly small (|v| < `mag`), a fraction
/// exactly zero — typical counters/flags/enum fields.
pub fn fill_small_ints(out: &mut [u8], mag: i64, zero_frac: f64, rng: &mut Rng) {
    for c in out.chunks_mut(4) {
        let v: i32 = if rng.chance(zero_frac) { 0 } else { rng.range_i64(-mag, mag) as i32 };
        let b = v.to_le_bytes();
        let n = c.len();
        c.copy_from_slice(&b[..n]);
    }
}

/// Fill with f32 values from a normal distribution — simulation state
/// (positions/velocities) whose sign+exponent bits cluster tightly.
pub fn fill_f32(out: &mut [u8], mean: f64, sd: f64, rng: &mut Rng) {
    for c in out.chunks_mut(4) {
        let v = rng.normal_ms(mean, sd) as f32;
        let b = v.to_le_bytes();
        let n = c.len();
        c.copy_from_slice(&b[..n]);
    }
}

/// Fill with f64 values (doubles dominate JVM numeric workloads).
pub fn fill_f64(out: &mut [u8], mean: f64, sd: f64, rng: &mut Rng) {
    for c in out.chunks_mut(8) {
        let v = rng.normal_ms(mean, sd);
        let b = v.to_le_bytes();
        let n = c.len();
        c.copy_from_slice(&b[..n]);
    }
}

/// Fill with f64 values drawn from a small quantized set (`levels` evenly
/// spaced values in `[0, scale]`) — one-hot/tf-idf features, star ratings,
/// normalized categorical data. Real ML datasets are full of these, and
/// their bit patterns cluster into a handful of exact values.
pub fn fill_f64_quantized(out: &mut [u8], levels: u64, scale: f64, rng: &mut Rng) {
    for c in out.chunks_mut(8) {
        let k = rng.zipf(levels, 0.9);
        let v = scale * (k as f64) / (levels.max(2) - 1) as f64;
        let b = v.to_le_bytes();
        let n = c.len();
        c.copy_from_slice(&b[..n]);
    }
}

/// Fill with a sparse f64 vector: `density` fraction non-zero (normal),
/// the rest exactly +0.0 — SVM alpha vectors, sparse gradients.
pub fn fill_sparse_f64(out: &mut [u8], density: f64, mean: f64, sd: f64, rng: &mut Rng) {
    for c in out.chunks_mut(8) {
        let v = if rng.chance(density) { rng.normal_ms(mean, sd) } else { 0.0 };
        let b = v.to_le_bytes();
        let n = c.len();
        c.copy_from_slice(&b[..n]);
    }
}

/// Fill with one repeated f32 constant (rest densities, boundary
/// conditions, initialized-but-unwritten simulation fields).
pub fn fill_f32_const(out: &mut [u8], value: f32) {
    let b = value.to_le_bytes();
    for (i, byte) in out.iter_mut().enumerate() {
        *byte = b[i % 4];
    }
}

/// Fill with ASCII text drawn from a Zipf vocabulary — interpreter/string
/// heavy regions (perlbench).
pub fn fill_text(out: &mut [u8], rng: &mut Rng) {
    const WORDS: [&str; 24] = [
        "the", "of", "and", "sub", "my", "return", "if", "else", "print", "regex", "hash",
        "array", "scalar", "push", "shift", "local", "foreach", "while", "string", "value",
        "key", "defined", "undef", "chomp",
    ];
    let mut i = 0;
    while i < out.len() {
        let word = WORDS[rng.zipf(WORDS.len() as u64, 1.2) as usize].as_bytes();
        let take = word.len().min(out.len() - i);
        out[i..i + take].copy_from_slice(&word[..take]);
        i += take;
        if i < out.len() {
            out[i] = b' ';
            i += 1;
        }
    }
}

/// Fill as an open-addressing hash table: `fill` fraction of fixed-size
/// entries occupied (key hash + pointer + small value), the rest zero —
/// the dominant layout in deepsjeng's transposition tables and freqmine's
/// hash trees.
pub fn fill_hash_table(out: &mut [u8], fill: f64, arena: &PointerArena, rng: &mut Rng) {
    const ENTRY: usize = 16; // 8B key/hash + 8B payload pointer
    for e in out.chunks_mut(ENTRY) {
        if !rng.chance(fill) {
            e.fill(0);
            continue;
        }
        let key = rng.next_u64();
        let ptr = arena.ptr(rng);
        let kb = key.to_le_bytes();
        let pb = ptr.to_le_bytes();
        let n = e.len().min(8);
        e[..n].copy_from_slice(&kb[..n]);
        if e.len() > 8 {
            let m = e.len() - 8;
            e[8..].copy_from_slice(&pb[..m]);
        }
    }
}

/// Fill with 64-bit bitboards / dense random words with occasional
/// repeated patterns (deepsjeng search state). Mostly incompressible by
/// design — chess engines keep high-entropy hashes.
pub fn fill_bitboards(out: &mut [u8], rng: &mut Rng) {
    let patterns: Vec<u64> = (0..8).map(|_| rng.next_u64()).collect();
    for c in out.chunks_mut(8) {
        let v = if rng.chance(0.25) {
            patterns[rng.below(8) as usize] // repeated board masks
        } else {
            rng.next_u64()
        };
        let b = v.to_le_bytes();
        let n = c.len();
        c.copy_from_slice(&b[..n]);
    }
}

/// Fill with monotone counters stepped with jitter (ids, sequence
/// numbers, simulation timestamps) — omnetpp event queues.
pub fn fill_counters(out: &mut [u8], start: u64, step: u64, rng: &mut Rng) {
    let mut v = start;
    for c in out.chunks_mut(8) {
        let b = v.to_le_bytes();
        let n = c.len();
        c.copy_from_slice(&b[..n]);
        v = v.wrapping_add(step + rng.below(step.max(1)));
    }
}

/// A weighted mixture of region fills applied page-by-page: the composer
/// walks the image in `page` chunks and dispatches each page to one
/// region kind, giving the inter-block locality GBDI targets (whole pages
/// share a population, different pages differ).
pub struct Composer<'a> {
    /// Page granularity (4096 matches real dumps).
    pub page: usize,
    /// (weight, fill function) pairs.
    pub parts: Vec<(f64, Box<dyn FnMut(&mut [u8], &mut Rng) + 'a>)>,
}

impl<'a> Composer<'a> {
    /// New composer with 4 KiB pages.
    pub fn new() -> Self {
        Composer { page: 4096, parts: Vec::new() }
    }

    /// Add a region kind with the given mixture weight.
    pub fn part(mut self, weight: f64, f: impl FnMut(&mut [u8], &mut Rng) + 'a) -> Self {
        self.parts.push((weight, Box::new(f)));
        self
    }

    /// Generate `bytes` of memory image.
    pub fn generate(mut self, bytes: usize, rng: &mut Rng) -> Vec<u8> {
        let weights: Vec<f64> = self.parts.iter().map(|(w, _)| *w).collect();
        let mut out = vec![0u8; bytes];
        for page in out.chunks_mut(self.page) {
            let k = rng.weighted(&weights);
            (self.parts[k].1)(page, rng);
        }
        out
    }
}

impl<'a> Default for Composer<'a> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::byte_entropy;

    #[test]
    fn pointer_arena_stays_in_bounds() {
        let mut rng = Rng::new(1);
        let a = PointerArena { base: 0x7F00_0000_0000, span: 1 << 20, align: 16 };
        for _ in 0..10_000 {
            let p = a.ptr(&mut rng);
            assert!(p >= a.base && p < a.base + a.span);
            assert_eq!(p % 16, 0);
        }
    }

    #[test]
    fn pointer_pages_have_clustered_high_words() {
        let mut rng = Rng::new(2);
        let a = PointerArena { base: 0x7F00_0000_0000, span: 1 << 24, align: 8 };
        let mut page = vec![0u8; 4096];
        fill_pointers(&mut page, &a, &mut rng);
        // every odd 32-bit word (pointer high half) should be identical
        let mut highs = std::collections::BTreeSet::new();
        for i in 0..page.len() / 8 {
            highs.insert(u32::from_le_bytes(page[i * 8 + 4..i * 8 + 8].try_into().unwrap()));
        }
        assert!(highs.len() <= 2, "high words {highs:?}");
    }

    #[test]
    fn small_ints_mostly_small() {
        let mut rng = Rng::new(3);
        let mut page = vec![0u8; 4096];
        fill_small_ints(&mut page, 100, 0.3, &mut rng);
        let mut zeros = 0;
        for i in 0..1024 {
            let v = i32::from_le_bytes(page[i * 4..i * 4 + 4].try_into().unwrap());
            assert!(v.abs() <= 100);
            if v == 0 {
                zeros += 1;
            }
        }
        assert!(zeros > 200, "zeros {zeros}");
    }

    #[test]
    fn f32_exponents_cluster() {
        let mut rng = Rng::new(4);
        let mut page = vec![0u8; 4096];
        fill_f32(&mut page, 1.0, 0.1, &mut rng);
        let mut exps = std::collections::BTreeSet::new();
        for i in 0..1024 {
            let bits = u32::from_le_bytes(page[i * 4..i * 4 + 4].try_into().unwrap());
            exps.insert((bits >> 23) & 0xFF);
        }
        assert!(exps.len() <= 6, "exponents {exps:?}");
    }

    #[test]
    fn text_is_ascii() {
        let mut rng = Rng::new(5);
        let mut page = vec![0u8; 1024];
        fill_text(&mut page, &mut rng);
        assert!(page.iter().all(|&b| b.is_ascii_lowercase() || b == b' '));
        let e = byte_entropy(&page);
        assert!(e < 5.0, "text entropy {e}");
    }

    #[test]
    fn hash_table_fill_fraction_respected() {
        let mut rng = Rng::new(6);
        let a = PointerArena { base: 0x1000_0000, span: 1 << 20, align: 8 };
        let mut page = vec![0u8; 1 << 16];
        fill_hash_table(&mut page, 0.3, &a, &mut rng);
        let empty = page.chunks(16).filter(|e| e.iter().all(|&b| b == 0)).count();
        let frac = empty as f64 / (page.len() / 16) as f64;
        assert!((frac - 0.7).abs() < 0.05, "empty frac {frac}");
    }

    #[test]
    fn bitboards_high_entropy() {
        let mut rng = Rng::new(7);
        let mut page = vec![0u8; 1 << 14];
        fill_bitboards(&mut page, &mut rng);
        assert!(byte_entropy(&page) > 7.0);
    }

    #[test]
    fn counters_monotone() {
        let mut rng = Rng::new(8);
        let mut page = vec![0u8; 4096];
        fill_counters(&mut page, 1000, 10, &mut rng);
        let mut prev = 0u64;
        for i in 0..page.len() / 8 {
            let v = u64::from_le_bytes(page[i * 8..i * 8 + 8].try_into().unwrap());
            assert!(v > prev);
            prev = v;
        }
    }

    #[test]
    fn composer_mixes_deterministically() {
        let build = |seed| {
            let mut rng = Rng::new(seed);
            Composer::new()
                .part(1.0, |p, r| fill_small_ints(p, 50, 0.2, r))
                .part(1.0, |p, _| p.fill(0))
                .generate(1 << 16, &mut rng)
        };
        assert_eq!(build(9), build(9));
        assert_ne!(build(9), build(10));
        assert_eq!(build(9).len(), 1 << 16);
    }
}
