//! Calibration harness: prints per-workload ratios for GBDI + baselines.
//! Run with `cargo test --release -p gbdi calibrate_print -- --ignored --nocapture`.

#[cfg(test)]
mod tests {
    use crate::baselines::{ratio_of, Codec, GbdiWholeImage};
    use crate::workloads;

    #[test]
    #[ignore = "calibration tool, not a correctness test"]
    fn calibrate_print() {
        let size = 1 << 21; // 2 MiB per workload: fast but representative
        let gbdi = GbdiWholeImage::default();
        let bdi = crate::baselines::bdi::Bdi::default();
        println!("\n{:<22} {:>7} {:>7}", "workload", "gbdi", "bdi");
        let mut c_ratios = Vec::new();
        let mut j_ratios = Vec::new();
        for w in workloads::all() {
            let img = w.generate(size, 7);
            let rg = ratio_of(&gbdi, &img);
            let rb = ratio_of(&bdi as &dyn Codec, &img);
            println!("{:<22} {:>7.3} {:>7.3}", w.name(), rg, rb);
            if w.group().is_c_family() {
                c_ratios.push(rg);
            } else {
                j_ratios.push(rg);
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        println!(
            "C mean {:.3} (paper 1.4) | Java mean {:.3} (paper 1.55) | overall {:.3} (paper 1.45)",
            mean(&c_ratios),
            mean(&j_ratios),
            mean(&[c_ratios.clone(), j_ratios.clone()].concat())
        );
    }
}
