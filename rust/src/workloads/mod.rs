//! The paper's nine evaluation workloads as synthetic memory-image
//! generators (substitution documented in DESIGN.md §2: we model each
//! application's characteristic in-memory value population; GBDI's ratio
//! depends on that population, not on which binary produced the bytes).
//!
//! * SPEC CPU 2017: `mcf`, `perlbench`, `omnetpp`, `deepsjeng`
//! * PARSEC: `fluidanimate`, `freqmine`
//! * Java: `triangle_count`, `svm`, `matrix_factorization`

pub mod java;
pub mod parsec;
pub mod regions;
pub mod spec;

use crate::util::prng::Rng;

/// Workload family, for the paper's per-group aggregate claims
/// (1.55× Java vs 1.4× C-workloads).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Group {
    /// SPEC CPU 2017 (C/C++).
    SpecCpu,
    /// PARSEC (C/C++).
    Parsec,
    /// Java / JVM workloads.
    Java,
}

impl Group {
    /// Display label.
    pub fn label(self) -> &'static str {
        match self {
            Group::SpecCpu => "SPEC CPU 2017",
            Group::Parsec => "PARSEC",
            Group::Java => "Java",
        }
    }

    /// Whether the paper counts this group under "C-Workloads".
    pub fn is_c_family(self) -> bool {
        matches!(self, Group::SpecCpu | Group::Parsec)
    }
}

/// A synthetic workload: generates memory images with the application's
/// characteristic value structure.
pub trait Workload: Send + Sync {
    /// Short name used on the CLI and in reports (e.g. `"mcf"`).
    fn name(&self) -> &'static str;
    /// Benchmark family.
    fn group(&self) -> Group;
    /// The dump file the paper used, for the report mapping.
    fn paper_dump(&self) -> &'static str;
    /// One-line description of the modelled memory content.
    fn description(&self) -> &'static str;
    /// Generate `bytes` of memory image, deterministic in `seed`.
    fn generate(&self, bytes: usize, seed: u64) -> Vec<u8>;
}

/// All nine workloads in the paper's presentation order.
pub fn all() -> Vec<Box<dyn Workload>> {
    vec![
        Box::new(spec::Mcf),
        Box::new(spec::Perlbench),
        Box::new(spec::Omnetpp),
        Box::new(spec::Deepsjeng),
        Box::new(parsec::Fluidanimate),
        Box::new(parsec::Freqmine),
        Box::new(java::TriangleCount),
        Box::new(java::Svm),
        Box::new(java::MatrixFactorization),
    ]
}

/// Look up a workload by name (case-insensitive).
pub fn by_name(name: &str) -> Option<Box<dyn Workload>> {
    let lower = name.to_ascii_lowercase();
    all().into_iter().find(|w| w.name() == lower)
}

/// Derive a per-workload RNG from a user seed (stable across runs and
/// independent across workloads).
pub(crate) fn workload_rng(name: &str, seed: u64) -> Rng {
    let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a over the name
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    Rng::new(h ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::byte_entropy;

    #[test]
    fn registry_complete_and_ordered() {
        let names: Vec<&str> = all().iter().map(|w| w.name()).collect();
        assert_eq!(
            names,
            vec![
                "mcf",
                "perlbench",
                "omnetpp",
                "deepsjeng",
                "fluidanimate",
                "freqmine",
                "triangle_count",
                "svm",
                "matrix_factorization"
            ]
        );
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("mcf").is_some());
        assert!(by_name("MCF").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn groups_match_paper() {
        for w in all() {
            let expected = match w.name() {
                "mcf" | "perlbench" | "omnetpp" | "deepsjeng" => Group::SpecCpu,
                "fluidanimate" | "freqmine" => Group::Parsec,
                _ => Group::Java,
            };
            assert_eq!(w.group(), expected, "{}", w.name());
        }
        assert!(Group::SpecCpu.is_c_family());
        assert!(Group::Parsec.is_c_family());
        assert!(!Group::Java.is_c_family());
    }

    #[test]
    fn generation_deterministic_and_sized() {
        for w in all() {
            let a = w.generate(1 << 16, 42);
            let b = w.generate(1 << 16, 42);
            let c = w.generate(1 << 16, 43);
            assert_eq!(a.len(), 1 << 16, "{}", w.name());
            assert_eq!(a, b, "{} deterministic", w.name());
            assert_ne!(a, c, "{} seed-sensitive", w.name());
        }
    }

    #[test]
    fn images_are_neither_trivial_nor_random() {
        // every workload image must have structure (entropy well below 8)
        // but not be degenerate (entropy above 1)
        for w in all() {
            let img = w.generate(1 << 18, 7);
            let e = byte_entropy(&img);
            assert!(e > 0.5, "{} entropy {e} too low", w.name());
            assert!(e < 7.9, "{} entropy {e} too high", w.name());
        }
    }

    #[test]
    fn paper_dump_names_present() {
        for w in all() {
            assert!(w.paper_dump().contains("dump"), "{}", w.name());
            assert!(!w.description().is_empty());
        }
    }
}
mod calibrate;
