//! PARSEC workload models: `fluidanimate` (SPH fluid simulation) and
//! `freqmine` (FP-growth frequent itemset mining).

use super::regions::*;
use super::{workload_rng, Group, Workload};

/// `fluidanimate`: smoothed-particle hydrodynamics. Memory is dominated
//  by SoA float arrays (positions, velocities, densities) whose values
/// share sign/exponent bits, plus cell-grid index arrays.
pub struct Fluidanimate;

impl Workload for Fluidanimate {
    fn name(&self) -> &'static str {
        "fluidanimate"
    }
    fn group(&self) -> Group {
        Group::Parsec
    }
    fn paper_dump(&self) -> &'static str {
        "parsec_fluidanimate5dump"
    }
    fn description(&self) -> &'static str {
        "SPH fluid sim: f32 position/velocity/density SoA + cell indices"
    }
    fn generate(&self, bytes: usize, seed: u64) -> Vec<u8> {
        let mut rng = workload_rng(self.name(), seed);
        Composer::new()
            // positions in a [0, 0.3m] box
            .part(2.0, |p, r| fill_f32(p, 0.15, 0.08, r))
            // velocities near zero
            .part(1.5, |p, r| fill_f32(p, 0.0, 0.02, r))
            // densities around rest density 1000
            .part(1.0, |p, r| fill_f32(p, 1000.0, 30.0, r))
            // rest-density / boundary constants and freshly-initialized
            // fields: one repeated f32 per page (REP blocks)
            .part(1.2, |p, r| {
                let v = [1000.0f32, 0.0, 0.1, 9.8][r.below(4) as usize];
                fill_f32_const(p, v)
            })
            // cell grid: particle indices (bounded ints)
            .part(1.5, |p, r| fill_small_ints(p, 500_000, 0.15, r))
            .part(1.3, |p, _| p.fill(0))
            .part(0.3, |p, r| r.fill_bytes(p))
            .generate(bytes, &mut rng)
    }
}

/// `freqmine`: FP-growth. Memory is an FP-tree of nodes (item id, count,
/// parent/child/sibling pointers) plus header tables and transaction
/// buffers.
pub struct Freqmine;

impl Workload for Freqmine {
    fn name(&self) -> &'static str {
        "freqmine"
    }
    fn group(&self) -> Group {
        Group::Parsec
    }
    fn paper_dump(&self) -> &'static str {
        "parsec_freqmine5dump"
    }
    fn description(&self) -> &'static str {
        "FP-growth tree: item/count nodes with parent/child pointers"
    }
    fn generate(&self, bytes: usize, seed: u64) -> Vec<u8> {
        let mut rng = workload_rng(self.name(), seed);
        let tree = PointerArena { base: 0x7FBB_0000_0000, span: 1 << 27, align: 48 };
        Composer::new()
            // FP-tree nodes: 48 bytes = item(4) count(4) + 3 pointers + pad
            .part(4.0, move |p, r| {
                for node in p.chunks_mut(48) {
                    if node.len() < 48 {
                        fill_small_ints(node, 1000, 0.2, r);
                        continue;
                    }
                    let item = r.zipf(10_000, 1.1) as u32; // zipf item ids
                    let count = (1 + r.zipf(100_000, 1.3)) as u32;
                    node[0..4].copy_from_slice(&item.to_le_bytes());
                    node[4..8].copy_from_slice(&count.to_le_bytes());
                    node[8..16].copy_from_slice(&tree.ptr(r).to_le_bytes());
                    node[16..24].copy_from_slice(&tree.ptr(r).to_le_bytes());
                    node[24..32].copy_from_slice(&tree.ptr(r).to_le_bytes());
                    node[32..48].fill(0); // padding/alignment slack
                }
            })
            // header table: item -> node-list head pointers
            .part(1.5, move |p, r| fill_hash_table(p, 0.6, &tree, r))
            // transaction scratch: small item ids
            .part(1.5, |p, r| fill_small_ints(p, 10_000, 0.1, r))
            .part(1.0, |p, _| p.fill(0))
            .generate(bytes, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{ratio_of, GbdiWholeImage};

    #[test]
    fn fluidanimate_float_pages_cluster() {
        let img = Fluidanimate.generate(1 << 20, 1);
        let r = ratio_of(&GbdiWholeImage::default(), &img);
        assert!(r > 1.1, "fluidanimate ratio {r}");
    }

    #[test]
    fn freqmine_compresses_above_one() {
        let img = Freqmine.generate(1 << 20, 1);
        let r = ratio_of(&GbdiWholeImage::default(), &img);
        assert!(r > 1.2, "freqmine ratio {r}");
    }

    #[test]
    fn images_sized_correctly() {
        assert_eq!(Fluidanimate.generate(12345, 5).len(), 12345);
        assert_eq!(Freqmine.generate(12345, 5).len(), 12345);
    }
}
