//! The compression service: ingest queue → worker pool → versioned store,
//! generic over the unified [`BlockCodec`] seam. Two modes:
//!
//! * **Adaptive GBDI** ([`CompressionService::start`]) — workers compress
//!   against the current global base table while a background analyzer
//!   re-derives it from sampled traffic and swaps in better versions.
//! * **Static codec** ([`CompressionService::start_static`]) — any
//!   [`BlockCodec`] (BDI, FPC, or a pinned GBDI table) with no analyzer
//!   thread; the baseline-serving arm of the E3 comparison.
//!
//! Threading model (all std, no async runtime available offline):
//!
//! ```text
//!  submit() / submit_batch()  ──mpsc──►  workers (N threads)
//!                         │  read current Arc<dyn BlockCodec> (RwLock swap)
//!                         │  compress the whole batch OUTSIDE any store
//!                         │  lock, then put_batch → ShardedPageStore:
//!                         │  pages grouped per shard, each shard lock
//!                         │  taken once per batch
//!                         │  feed word samples → Reservoir (Mutex)
//!                         ▼
//!  ShardedPageStore (S shards, page-id hash routing): block GETs take
//!  one shard's read side, block PUTs one shard's write side — traffic
//!  on different shards never contends, and a codec publish is one O(1)
//!  insert into the shared ring (DESIGN.md §8).
//!
//!  analyzer thread (adaptive mode only): every `analyze_every` pages,
//!  snapshot the reservoir; if drift detection says the incumbent still
//!  scores well, skip; otherwise run the configured BaseSelector
//!  (lloyd / minibatch warm-start / histogram / PJRT artifact), fit
//!  widths, score vs incumbent, publish new version + swap codec.
//!  Recompression migration walks one shard at a time
//!  ([`CompressionService::recompress_step`]), so maintenance never
//!  stalls foreground GETs/PUTs on other shards.
//! ```

use super::analyzer::Analyzer;
use crate::cluster::{BaseSelector, SelectorKind};
use super::metrics::{CacheTotals, IntegrityTotals, Metrics, MetricsSnapshot, ShardMetricsSnapshot};
use super::store::{IntegrityConfig, ScrubOutcome, ShardedPageStore, StoredPage};
use crate::codec::{BlockCodec, Scratch};
use crate::frame::Frame;
use crate::gbdi::table::GlobalBaseTable;
use crate::gbdi::{GbdiCodec, GbdiConfig};
use crate::persist::{self, Durability, WalRecord};
use crate::util::prng::Rng;
use crate::util::stats::Reservoir;
use crate::value::words;
use crate::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Codec configuration (shared by all GBDI versions; supplies the
    /// sampling word size in static mode too).
    pub codec: GbdiConfig,
    /// Compression worker threads.
    pub workers: usize,
    /// Run an analysis after this many newly ingested pages.
    pub analyze_every: u64,
    /// Reservoir size for traffic sampling (words).
    pub sample_words: usize,
    /// Pages migrated to the newest codec per maintenance step.
    pub recompress_batch: usize,
    /// Base selector the adaptive analyzer runs (adaptive mode only).
    pub selector: SelectorKind,
    /// Drift-detection margin: re-clustering is skipped while fresh
    /// samples score within this factor of the adopted table's baseline.
    pub drift_margin: f64,
    /// Swap hysteresis: a candidate must shrink estimated bits below
    /// `incumbent * swap_margin` to be published.
    pub swap_margin: f64,
    /// Independently locked shards of the page store (clamped to ≥ 1).
    /// More shards = less lock contention between concurrent block
    /// GETs/PUTs and ingest; 1 reproduces the old single-lock behavior.
    pub shards: usize,
    /// Preferred pages per [`CompressionService::submit_batch`] call —
    /// the grouping the CLI and benches use when streaming ingest.
    /// Workers take each shard lock once per batch instead of once per
    /// page, so larger batches amortize locking at the cost of ingest
    /// latency.
    pub ingest_batch: usize,
    /// Total bytes of the hot-block cache tier, split evenly across the
    /// shards ([`ShardedPageStore::with_cache`]). 0 (the default)
    /// disables the cache entirely: block reads and writes go straight
    /// to the compressed frames, bit-identical to a cacheless build.
    pub cache_bytes: usize,
    /// Durability engine (`gbdi serve --data-dir`): when set, the
    /// service adopts the store recovered by [`Durability::open`],
    /// WAL-logs every mutation before applying it, checkpoints when the
    /// WAL outgrows its limit, and takes a final checkpoint on
    /// shutdown. `None` (the default) keeps every serving path
    /// bit-identical to a persistence-free build.
    pub persist: Option<Arc<Durability>>,
    /// In-memory integrity plane (`[integrity]` config section,
    /// DESIGN.md §13): per-page CRC digests maintained incrementally by
    /// the store ([`ShardedPageStore::with_integrity`]), optional
    /// verification on every read, and a background scrubber paced to
    /// [`IntegrityConfig::scrub_mib_s`]. Pages that fail verification
    /// are quarantined — reads answer [`crate::Error::DataLoss`], never
    /// possibly-wrong bytes — and healed from durable state when
    /// [`ServiceConfig::persist`] is attached. Disabled by default:
    /// every path stays bit-identical to a digest-free build.
    pub integrity: IntegrityConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            codec: GbdiConfig::default(),
            workers: 4,
            analyze_every: 256,
            sample_words: 8192,
            recompress_batch: 64,
            selector: SelectorKind::Lloyd,
            drift_margin: 1.02,
            swap_margin: 0.98,
            shards: 8,
            ingest_batch: 32,
            cache_bytes: 0,
            persist: None,
            integrity: IntegrityConfig::default(),
        }
    }
}

struct Shared {
    codec: RwLock<Arc<dyn BlockCodec>>,
    store: ShardedPageStore,
    reservoir: Mutex<Reservoir<u64>>,
    metrics: Metrics,
    config: ServiceConfig,
    pages_since_analysis: AtomicU64,
    next_version: AtomicU64,
    inflight: AtomicU64,
    idle: Condvar,
    idle_lock: Mutex<()>,
    analyze_now: AtomicBool,
    shutdown: AtomicBool,
}

enum Job {
    /// One ingest batch: compressed together by one worker, stored with
    /// one shard-lock acquisition per touched shard.
    Batch(Vec<(u64, Vec<u8>)>),
}

/// The running service.
///
/// ```
/// use gbdi::coordinator::{CompressionService, ServiceConfig};
///
/// let svc = CompressionService::start(ServiceConfig {
///     workers: 2,
///     shards: 4,
///     ..Default::default()
/// })
/// .unwrap();
/// // ingest: single pages or per-shard-batched
/// svc.submit(0, vec![0u8; 4096]);
/// svc.submit_batch((1..4u64).map(|i| (i, vec![i as u8; 4096])).collect());
/// svc.flush();
/// assert_eq!(svc.read_page(2).unwrap(), vec![2u8; 4096]);
/// // block-granular serving straight out of the compressed frames
/// let mut line = [0u8; 64];
/// svc.read_block(0, 3, &mut line).unwrap();
/// svc.write_block(0, 3, &[7u8; 64]).unwrap();
/// let metrics = svc.shutdown();
/// assert_eq!(metrics.pages_in, 4);
/// assert_eq!(metrics.block_reads, 1);
/// assert_eq!(metrics.block_writes, 1);
/// ```
pub struct CompressionService {
    shared: Arc<Shared>,
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    analyzer: Option<JoinHandle<()>>,
    scrubber: Option<JoinHandle<()>>,
}

impl CompressionService {
    /// Start the adaptive GBDI service with an initial table derived from
    /// nothing (the pinned zero base only); the analyzer will improve it
    /// as traffic arrives, running the selector named by
    /// `config.selector`.
    pub fn start(config: ServiceConfig) -> Result<Self> {
        let selector = config.selector.build();
        Self::start_with_selector(config, selector)
    }

    /// [`Self::start`] with an explicit selector instance — the hook for
    /// selectors that need external state, e.g.
    /// [`crate::cluster::ArtifactSelector`] over a PJRT runtime.
    pub fn start_with_selector(
        config: ServiceConfig,
        selector: Box<dyn BaseSelector>,
    ) -> Result<Self> {
        config.codec.validate().map_err(crate::Error::Config)?;
        let initial = GlobalBaseTable::new(vec![(0, 8)], config.codec.word_size, 0);
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(initial, config.codec.clone()));
        let mut analyzer = Analyzer::new(selector, config.codec.clone());
        analyzer.swap_margin = config.swap_margin;
        analyzer.drift_margin = config.drift_margin;
        Self::start_inner(config, codec, Some(analyzer))
    }

    /// Start the service over a fixed codec — any [`BlockCodec`] — with
    /// no background analyzer. Pages are compressed and versioned exactly
    /// like the adaptive path, so reads, accounting, and recompression
    /// behave identically.
    pub fn start_static(config: ServiceConfig, codec: Arc<dyn BlockCodec>) -> Result<Self> {
        config.codec.validate().map_err(crate::Error::Config)?;
        Self::start_inner(config, codec, None)
    }

    fn start_inner(
        config: ServiceConfig,
        codec: Arc<dyn BlockCodec>,
        analyzer: Option<Analyzer>,
    ) -> Result<Self> {
        let mut codec = codec;
        let store = match config.persist.as_ref().and_then(|d| d.take_store()) {
            Some(recovered) => {
                // adaptive mode resumes from the newest recovered table
                // version instead of re-learning from scratch; static
                // mode keeps its pinned codec (recovered GBDI tables
                // stay in the ring so old pages still decode)
                if analyzer.is_some() {
                    let best = recovered
                        .codecs()
                        .into_iter()
                        .filter(|c| c.global_table().is_some())
                        .max_by_key(|c| c.version());
                    if let Some(best) = best {
                        if best.version() > codec.version() {
                            codec = best;
                        }
                    }
                }
                recovered
            }
            None => {
                let mut store = ShardedPageStore::new(config.shards);
                if config.cache_bytes > 0 {
                    store = store.with_cache(config.cache_bytes);
                }
                store
            }
        };
        // attach the integrity plane to whichever store we ended up with
        // (no-op builder when disabled): a recovered store gets its
        // digests backfilled here, so scrubbing covers recovered pages
        let store = store.with_integrity(config.integrity.clone());
        let first_version = store
            .codecs()
            .iter()
            .map(|c| c.version())
            .max()
            .unwrap_or(0)
            .max(codec.version());
        store.publish_codec(Arc::clone(&codec));
        let shared = Arc::new(Shared {
            codec: RwLock::new(codec),
            store,
            reservoir: Mutex::new(Reservoir::new(config.sample_words)),
            metrics: Metrics::new(),
            config: config.clone(),
            pages_since_analysis: AtomicU64::new(0),
            next_version: AtomicU64::new(first_version + 1),
            inflight: AtomicU64::new(0),
            idle: Condvar::new(),
            idle_lock: Mutex::new(()),
            analyze_now: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
        });

        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..config.workers.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("gbdi-compress-{i}"))
                    .spawn(move || worker_loop(shared, rx, i as u64))
                    .expect("spawn worker")
            })
            .collect();

        let analyzer_handle = analyzer.map(|mut analyzer| {
            let analyzer_shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("gbdi-analyzer".into())
                .spawn(move || analyzer_loop(analyzer_shared, &mut analyzer))
                .expect("spawn analyzer")
        });

        let scrubber_handle = if config.integrity.enabled {
            let scrub_shared = Arc::clone(&shared);
            Some(
                std::thread::Builder::new()
                    .name("gbdi-scrub".into())
                    .spawn(move || scrub_loop(scrub_shared))
                    .expect("spawn scrubber"),
            )
        } else {
            None
        };

        Ok(CompressionService {
            shared,
            tx: Some(tx),
            workers,
            analyzer: analyzer_handle,
            scrubber: scrubber_handle,
        })
    }

    /// Submit one page for compression (non-blocking). Equivalent to a
    /// batch of one; streaming callers should group pages with
    /// [`Self::submit_batch`] (see [`ServiceConfig::ingest_batch`]) so
    /// workers amortize shard locking.
    pub fn submit(&self, page_id: u64, data: Vec<u8>) {
        self.submit_batch(vec![(page_id, data)]);
    }

    /// Submit a batch of pages for compression (non-blocking). One
    /// worker compresses the whole batch outside any store lock, then
    /// stores it with **one lock acquisition per touched shard** —
    /// under concurrent ingest this is what keeps workers from
    /// serializing on the store. An empty batch is a no-op.
    pub fn submit_batch(&self, pages: Vec<(u64, Vec<u8>)>) {
        if pages.is_empty() {
            return;
        }
        self.shared.inflight.fetch_add(pages.len() as u64, Ordering::AcqRel);
        self.tx
            .as_ref()
            .expect("service running")
            .send(Job::Batch(pages))
            .expect("workers alive");
    }

    /// Block until every submitted page has been stored.
    pub fn flush(&self) {
        let mut guard = self.shared.idle_lock.lock().unwrap();
        while self.shared.inflight.load(Ordering::Acquire) > 0 {
            guard = self.shared.idle.wait(guard).unwrap();
        }
    }

    /// Read back a page (bit-exact), whatever codec version encoded it.
    /// A page quarantined by the integrity plane is healed from durable
    /// state first when persistence is attached; only when no durable
    /// copy exists does the caller see [`crate::Error::DataLoss`].
    pub fn read_page(&self, page_id: u64) -> Result<Vec<u8>> {
        let mut r = self.shared.store.read(page_id);
        if matches!(r, Err(crate::Error::DataLoss(_))) && try_heal(&self.shared, page_id) {
            r = self.shared.store.read(page_id);
        }
        if r.is_err() {
            self.shared.metrics.read_error();
        }
        r
    }

    /// [`Self::read_page`] into a caller-owned buffer: `out` is cleared
    /// and refilled, so a loop reusing one `Vec` decompresses page after
    /// page without allocating once the buffer has grown to page size.
    pub fn read_page_into(&self, page_id: u64, out: &mut Vec<u8>) -> Result<()> {
        let mut r = self.shared.store.read_into(page_id, out);
        if matches!(r, Err(crate::Error::DataLoss(_))) && try_heal(&self.shared, page_id) {
            r = self.shared.store.read_into(page_id, out);
        }
        if r.is_err() {
            self.shared.metrics.read_error();
        }
        r
    }

    /// Serve a single-block GET: decode one block of a stored page into
    /// `out` (returns the bytes written) without touching the rest of
    /// the page. O(1) in the page size, contending only with writers of
    /// the same shard; per-request latency lands in
    /// [`MetricsSnapshot::block_read_mean_ns`] and in that shard's
    /// [`ShardMetricsSnapshot`].
    pub fn read_block(&self, page_id: u64, block: usize, out: &mut [u8]) -> Result<usize> {
        let t0 = Instant::now();
        let mut r = self.shared.store.read_block(page_id, block, out);
        if matches!(r, Err(crate::Error::DataLoss(_))) && try_heal(&self.shared, page_id) {
            r = self.shared.store.read_block(page_id, block, out);
        }
        if r.is_err() {
            self.shared.metrics.read_error();
        } else {
            self.shared.metrics.block_read(t0.elapsed().as_nanos() as u64);
        }
        r
    }

    /// Serve a single-block PUT: recompress one block of a stored page
    /// in place under the codec version that encoded the page (the new
    /// encoding spills to the frame's patch region if it outgrows its
    /// slot). Takes only that page's shard lock. Latency lands in
    /// [`MetricsSnapshot::block_write_mean_ns`] and in that shard's
    /// [`ShardMetricsSnapshot`].
    pub fn write_block(&self, page_id: u64, block: usize, data: &[u8]) -> Result<()> {
        let t0 = Instant::now();
        let mut r = self.write_block_logged(page_id, block, data);
        // a quarantined page rejects block writes (the rest of its image
        // is untrustworthy); heal it from durable state and retry. The
        // retried write re-logs its WAL record — replay applies absolute
        // block writes idempotently, so the duplicate is harmless.
        if matches!(r, Err(crate::Error::DataLoss(_))) && try_heal(&self.shared, page_id) {
            r = self.write_block_logged(page_id, block, data);
        }
        match r {
            Ok(_) => {
                self.shared.metrics.block_write(t0.elapsed().as_nanos() as u64);
                Ok(())
            }
            Err(e) => {
                self.shared.metrics.write_error();
                Err(e)
            }
        }
    }

    fn write_block_logged(
        &self,
        page_id: u64,
        block: usize,
        data: &[u8],
    ) -> Result<crate::frame::BlockWrite> {
        match &self.shared.config.persist {
            None => self.shared.store.write_block(page_id, block, data),
            Some(d) => {
                // log-before-apply under the gate; a log failure fails
                // the write. Logging a write the store then rejects
                // (missing page) is harmless: replay rejects it the
                // same way and counts a replay error.
                let logged = {
                    let _gate = d.gate();
                    d.log(&WalRecord::WriteBlock {
                        page_id,
                        block: block as u32,
                        data: data.to_vec(),
                    })
                    .and_then(|()| self.shared.store.write_block(page_id, block, data))
                };
                if logged.is_ok() {
                    let _ = d.maybe_checkpoint(&self.shared.store);
                }
                logged
            }
        }
    }

    /// Pages accepted by [`Self::submit`] / [`Self::submit_batch`] but
    /// not yet compressed and stored — the ingest backlog. The network
    /// front end's admission control sheds batch PUTs against this
    /// gauge instead of letting the queue grow without bound.
    pub fn inflight(&self) -> u64 {
        self.shared.inflight.load(Ordering::Acquire)
    }

    /// The configuration this service was started with.
    pub fn config(&self) -> &ServiceConfig {
        &self.shared.config
    }

    /// Force an analysis round at the next opportunity (no-op in static
    /// mode).
    pub fn request_analysis(&self) {
        self.shared.analyze_now.store(true, Ordering::Release);
    }

    /// Current codec version in use (GBDI: table version).
    pub fn current_version(&self) -> u64 {
        self.shared.codec.read().unwrap().version()
    }

    /// Name of the codec currently serving compressions.
    pub fn codec_name(&self) -> &'static str {
        self.shared.codec.read().unwrap().name()
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Per-shard metrics: occupancy, exclusive lock-hold time, and block
    /// read/write latency for each shard of the page store. The block-op
    /// counters sum to the [`Self::metrics`] totals.
    pub fn shard_metrics(&self) -> Vec<ShardMetricsSnapshot> {
        self.shared.store.shard_metrics()
    }

    /// Number of page-store shards this service was started with.
    pub fn shard_count(&self) -> usize {
        self.shared.store.shard_count()
    }

    /// Service-wide hot-block cache counters and gauges — the exact sum
    /// of the per-shard numbers in [`Self::shard_metrics`]. All zeros
    /// when the cache is disabled (`cache_bytes: 0`).
    pub fn cache_totals(&self) -> CacheTotals {
        self.shared.store.cache_totals()
    }

    /// Flush every deferred (dirty) cached block back through its
    /// compressed frame; cached copies stay resident but clean. Returns
    /// the number of blocks recompressed. No-op without a cache.
    pub fn flush_cache(&self) -> usize {
        self.shared.store.flush_cache()
    }

    /// Service-wide integrity counters — pages scrubbed, corruptions
    /// detected, pages healed, pages quarantined — the exact sum of the
    /// per-shard numbers in [`Self::shard_metrics`]. All zeros with the
    /// integrity plane off.
    pub fn integrity_totals(&self) -> IntegrityTotals {
        self.shared.store.integrity_totals()
    }

    /// Page ids currently fenced by the integrity plane (sorted). A page
    /// leaves this set when it is healed from durable state or fully
    /// overwritten by a PUT.
    pub fn quarantined_pages(&self) -> Vec<u64> {
        self.shared.store.quarantined_pages()
    }

    /// Re-verify one page's digest right now, off the scrubber's
    /// schedule. On a corrupt outcome the durable heal is attempted
    /// immediately (when persistence is attached). Returns
    /// [`ScrubOutcome::Skipped`] when the integrity plane is off, the
    /// page is absent, or it is already quarantined.
    pub fn scrub_page(&self, page_id: u64) -> ScrubOutcome {
        let out = self.shared.store.scrub_page(page_id);
        if matches!(out, ScrubOutcome::Corrupt { .. }) {
            try_heal(&self.shared, page_id);
        }
        out
    }

    /// Test-only chaos hook: flip one stored bit of `page_id`'s
    /// compressed image (`gbdi serve --chaos-corrupt`, the CI chaos
    /// smoke, and `tests/integrity.rs`). Returns whether a bit was
    /// flipped. Hidden because it exists to *create* the corruption the
    /// integrity plane detects.
    #[doc(hidden)]
    pub fn corrupt_page_block(&self, page_id: u64, block: usize, bit: u64) -> bool {
        self.shared.store.corrupt_page_block(page_id, block, bit)
    }

    /// Stored/logical byte accounting: (logical, stored, ratio). One
    /// lock acquisition per shard; each shard's contribution to both
    /// numbers comes from the same instant.
    pub fn storage_ratio(&self) -> (usize, usize, f64) {
        let (l, s) = self.shared.store.usage();
        (l, s, if s == 0 { 1.0 } else { l as f64 / s as f64 })
    }

    /// Migrate up to `config.recompress_batch` pages encoded under old
    /// codec versions to the current one, walking the shards one at a
    /// time so maintenance only ever blocks the shard it is migrating —
    /// foreground GETs/PUTs on every other shard proceed untouched, and
    /// even within a shard the lock drops between pages
    /// ([`ShardedPageStore::migrate_shard`]). Returns pages migrated.
    pub fn recompress_step(&self) -> Result<usize> {
        let codec = Arc::clone(&self.shared.codec.read().unwrap());
        let mut budget = self.shared.config.recompress_batch;
        let mut moved = 0;
        for shard in 0..self.shared.store.shard_count() {
            if budget == 0 {
                break;
            }
            let n = self.shared.store.migrate_shard(shard, &codec, budget)?;
            self.shared.metrics.recompressed(n as u64);
            moved += n;
            budget -= n;
        }
        Ok(moved)
    }

    /// Resize the page store to `shards` shards **online**: concurrent
    /// GETs/PUTs simply queue for the swap's duration, no restart and no
    /// lost writes (`tests/sharded_store.rs` exercises this under
    /// concurrent traffic). With persistence on, the resize is WAL-logged
    /// first so a crash replays into the same topology. Returns how many
    /// pages changed shard.
    pub fn resize_shards(&self, shards: usize) -> Result<usize> {
        match &self.shared.config.persist {
            None => Ok(self.shared.store.resize_shards(shards)),
            Some(d) => {
                let _gate = d.gate();
                d.log(&WalRecord::Resize { shards: shards.max(1) as u32 })?;
                Ok(self.shared.store.resize_shards(shards))
            }
        }
    }

    /// Fold the WAL into a fresh checkpoint now (no-op `Ok(0)` without
    /// persistence). Returns the new checkpoint epoch.
    pub fn checkpoint(&self) -> Result<u64> {
        match &self.shared.config.persist {
            None => Ok(0),
            Some(d) => d.checkpoint(&self.shared.store),
        }
    }

    /// Stop the service, joining all threads. Pending pages are drained
    /// first (the queue closes, workers finish what is buffered). With
    /// persistence on, a final checkpoint folds the WAL so the next open
    /// recovers from segments alone.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.flush();
        self.shared.shutdown.store(true, Ordering::Release);
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(a) = self.analyzer.take() {
            let _ = a.join();
        }
        if let Some(s) = self.scrubber.take() {
            let _ = s.join();
        }
        if let Some(d) = &self.shared.config.persist {
            let _ = d.checkpoint(&self.shared.store);
        }
        self.shared.metrics.snapshot()
    }
}

fn worker_loop(shared: Arc<Shared>, rx: Arc<Mutex<Receiver<Job>>>, worker_id: u64) {
    let mut rng = Rng::new(0xC0FFEE ^ worker_id);
    let mut scratch = Scratch::new();
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Job::Batch(pages) = match job {
            Ok(j) => j,
            Err(_) => break,
        };
        let n = pages.len() as u64;
        // sample traffic for the analyzer (cheap stride over each page,
        // one reservoir acquisition per batch)
        {
            let mut res = shared.reservoir.lock().unwrap();
            for (_, data) in &pages {
                for w in words(data, shared.config.codec.word_size).step_by(17) {
                    res.offer(w, &mut rng);
                }
            }
        }
        // compress the whole batch outside any store lock...
        let codec = Arc::clone(&shared.codec.read().unwrap());
        let mut staged: Vec<(u64, StoredPage)> = Vec::with_capacity(pages.len());
        for (page_id, data) in &pages {
            let t0 = Instant::now();
            let stored =
                StoredPage { frame: Frame::compress_with(Arc::clone(&codec), data, &mut scratch) };
            let out_len = stored.stored_len() as u64;
            shared.metrics.page(data.len() as u64, out_len, t0.elapsed().as_nanos() as u64);
            staged.push((*page_id, stored));
        }
        // ...then store it with one lock acquisition per touched shard.
        // With persistence on, the whole batch is WAL-logged under the
        // apply gate *before* it lands in the store — recovery can then
        // never observe a page the log does not know about.
        match &shared.config.persist {
            None => shared.store.put_batch(staged),
            Some(d) => {
                let logged = {
                    let _gate = d.gate();
                    let recs: Vec<WalRecord> =
                        staged.iter().map(|(id, p)| persist::wal_put_page(*id, p)).collect();
                    match d.log_all(&recs) {
                        Ok(()) => {
                            shared.store.put_batch(staged);
                            true
                        }
                        Err(_) => {
                            // an unlogged batch must not become readable
                            // state the WAL cannot reproduce: drop it and
                            // surface the loss as write errors
                            for _ in 0..n {
                                shared.metrics.write_error();
                            }
                            false
                        }
                    }
                };
                if logged {
                    let _ = d.maybe_checkpoint(&shared.store);
                }
            }
        }
        shared.pages_since_analysis.fetch_add(n, Ordering::AcqRel);
        if shared.inflight.fetch_sub(n, Ordering::AcqRel) == n {
            let _g = shared.idle_lock.lock().unwrap();
            shared.idle.notify_all();
        }
    }
}

fn analyzer_loop(shared: Arc<Shared>, analyzer: &mut Analyzer) {
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        let forced = shared.analyze_now.swap(false, Ordering::AcqRel);
        let due = forced
            || shared.pages_since_analysis.load(Ordering::Acquire)
                >= shared.config.analyze_every;
        if !due {
            std::thread::sleep(std::time::Duration::from_millis(2));
            continue;
        }
        shared.pages_since_analysis.store(0, Ordering::Release);
        let samples: Vec<u64> = {
            let res = shared.reservoir.lock().unwrap();
            res.items().to_vec()
        };
        if samples.is_empty() {
            continue;
        }
        // the adaptive loop only ever swaps GBDI tables; a static codec
        // never reaches this thread
        let incumbent = Arc::clone(&shared.codec.read().unwrap());
        let incumbent_table = incumbent.global_table();
        // drift detection: while the incumbent still scores within the
        // margin of its adoption baseline, skip the selector entirely
        // (explicit `request_analysis` calls bypass the check)
        if !forced {
            if let Some(table) = incumbent_table {
                if !analyzer.should_recluster(&samples, table) {
                    shared.metrics.analysis_skipped();
                    continue;
                }
            }
        }
        let version = shared.next_version.fetch_add(1, Ordering::AcqRel);
        let candidate = match analyzer.analyze_warm(&samples, incumbent_table, version) {
            Ok(t) => t,
            Err(_) => continue, // artifact missing/failing: stay on incumbent
        };
        let swap = match incumbent_table {
            Some(table) => analyzer.should_swap(&samples, table, &candidate),
            None => false,
        };
        shared.metrics.analysis(swap);
        if swap {
            analyzer.note_adopted(&samples, &candidate);
            let new_codec: Arc<dyn BlockCodec> =
                Arc::new(GbdiCodec::new(candidate, shared.config.codec.clone()));
            // WAL the table snapshot first (best effort: every PutPage
            // container embeds its own table, so recovery re-seeds the
            // ring from page records even if this append is lost)
            if let Some(d) = &shared.config.persist {
                let _gate = d.gate();
                let _ = d.log(&persist::wal_publish_codec(&new_codec));
            }
            // the ring is shared across shards, so publishing the new
            // version is one O(1) insert — no per-shard fan-out, no
            // store-wide stall
            shared.store.publish_codec(Arc::clone(&new_codec));
            *shared.codec.write().unwrap() = new_codec;
        }
    }
}

/// Try to restore a quarantined page from durable state: read its image
/// back through the targeted recovery path
/// ([`Durability::read_page`](crate::persist::Durability::read_page))
/// and hand it to [`ShardedPageStore::heal_page`], which re-verifies
/// and installs it only if the page is still fenced. Returns whether
/// the page was healed. `false` without persistence — there is nothing
/// to heal from, and the quarantine stands.
fn try_heal(shared: &Shared, page_id: u64) -> bool {
    let Some(d) = &shared.config.persist else {
        return false;
    };
    match d.read_page(page_id) {
        // heal_page re-verifies the candidate, counts the heal in that
        // shard's metrics, and installs only if the page is still fenced
        Ok(Some(page)) => shared.store.heal_page(page_id, page),
        _ => false,
    }
}

/// The background scrubber (integrity plane on): walk the shards
/// round-robin re-verifying every resident page's digest, paced so the
/// verification work stays under `scrub_mib_s` of compressed bytes per
/// second — after each page the thread sleeps off that page's share of
/// the budget, so scrubbing never bursts ahead of foreground traffic.
/// A page that fails is quarantined by the store; with persistence
/// attached the heal is attempted immediately. Shutdown is polled
/// between pages so the thread joins promptly.
fn scrub_loop(shared: Arc<Shared>) {
    let rate = shared.config.integrity.scrub_mib_s.max(1).saturating_mul(1 << 20);
    let mut shard_idx = 0usize;
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        let n = shared.store.shard_count();
        if shard_idx >= n {
            shard_idx = 0;
        }
        let ids = shared.store.shard_page_ids(shard_idx);
        shard_idx += 1;
        if ids.is_empty() {
            // nothing resident in this shard: idle briefly instead of
            // spinning over an empty store
            std::thread::sleep(std::time::Duration::from_millis(5));
            continue;
        }
        for id in ids {
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            let bytes = match shared.store.scrub_page(id) {
                ScrubOutcome::Clean { bytes } => bytes,
                ScrubOutcome::Corrupt { bytes } => {
                    try_heal(&shared, id);
                    bytes
                }
                ScrubOutcome::Skipped => 0,
            };
            // charge every scrub at least a token cost so a store full
            // of quarantined (Skipped) pages still paces instead of
            // spinning hot
            let ns = (bytes.max(256) as u64).saturating_mul(1_000_000_000) / rate;
            std::thread::sleep(std::time::Duration::from_nanos(ns));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn service(workers: usize) -> CompressionService {
        let cfg = ServiceConfig {
            workers,
            analyze_every: 16,
            ..Default::default()
        };
        CompressionService::start(cfg).unwrap()
    }

    #[test]
    fn pages_roundtrip_through_service() {
        let svc = service(2);
        let w = workloads::by_name("mcf").unwrap();
        let pages: Vec<Vec<u8>> = (0..64).map(|i| w.generate(4096, i)).collect();
        for (i, p) in pages.iter().enumerate() {
            svc.submit(i as u64, p.clone());
        }
        svc.flush();
        for (i, p) in pages.iter().enumerate() {
            assert_eq!(&svc.read_page(i as u64).unwrap(), p, "page {i}");
        }
        let m = svc.shutdown();
        assert_eq!(m.pages_in, 64);
        assert!(m.ratio() > 1.0, "ratio {}", m.ratio());
    }

    #[test]
    fn static_codec_services_roundtrip() {
        // the same service machinery runs any BlockCodec
        let w = workloads::by_name("perlbench").unwrap();
        let codecs: Vec<Arc<dyn BlockCodec>> = vec![
            Arc::new(crate::baselines::bdi::Bdi::default()),
            Arc::new(crate::baselines::fpc::FpcBlock::default()),
        ];
        for codec in codecs {
            let name = codec.name();
            let svc = CompressionService::start_static(
                ServiceConfig { workers: 2, ..Default::default() },
                codec,
            )
            .unwrap();
            assert_eq!(svc.codec_name(), name);
            for i in 0..32u64 {
                svc.submit(i, w.generate(4096, i));
            }
            svc.flush();
            for i in 0..32u64 {
                assert_eq!(svc.read_page(i).unwrap(), w.generate(4096, i), "{name} page {i}");
            }
            // no analyzer: version stays pinned, analysis requests are no-ops
            svc.request_analysis();
            assert_eq!(svc.current_version(), 0);
            let m = svc.shutdown();
            assert_eq!(m.pages_in, 32);
            assert_eq!(m.table_swaps, 0);
        }
    }

    #[test]
    fn analyzer_improves_table_over_time() {
        let svc = service(2);
        let w = workloads::by_name("triangle_count").unwrap();
        // first wave: tables start trivial
        for i in 0..64u64 {
            svc.submit(i, w.generate(4096, i));
        }
        svc.flush();
        svc.request_analysis();
        // give the analyzer a moment, then ingest a second wave
        for _ in 0..200 {
            if svc.current_version() > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(svc.current_version() > 0, "analyzer never swapped");
        for i in 64..128u64 {
            svc.submit(i, w.generate(4096, i));
        }
        svc.flush();
        // all pages still readable (old + new version coexist)
        for i in 0..128u64 {
            assert_eq!(svc.read_page(i).unwrap(), w.generate(4096, i));
        }
        let m = svc.shutdown();
        assert!(m.table_swaps >= 1);
    }

    #[test]
    fn recompression_migrates_old_pages() {
        let svc = service(1);
        let w = workloads::by_name("svm").unwrap();
        for i in 0..32u64 {
            svc.submit(i, w.generate(4096, i));
        }
        svc.flush();
        svc.request_analysis();
        for _ in 0..200 {
            if svc.current_version() > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let mut total = 0;
        loop {
            let n = svc.recompress_step().unwrap();
            total += n;
            if n == 0 {
                break;
            }
        }
        assert!(total >= 32, "migrated {total}");
        for i in 0..32u64 {
            assert_eq!(svc.read_page(i).unwrap(), w.generate(4096, i));
        }
        let m = svc.shutdown();
        assert!(m.recompressions >= 32);
    }

    #[test]
    fn block_gets_and_puts_survive_table_swaps() {
        let svc = service(2);
        let w = workloads::by_name("triangle_count").unwrap();
        let pages: Vec<Vec<u8>> = (0..48).map(|i| w.generate(4096, i)).collect();
        for (i, p) in pages.iter().enumerate() {
            svc.submit(i as u64, p.clone());
        }
        svc.flush();
        // force a table swap so stored pages span codec versions
        svc.request_analysis();
        for _ in 0..200 {
            if svc.current_version() > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(svc.current_version() > 0, "analyzer never swapped");
        for (i, p) in pages.iter().enumerate().take(8) {
            svc.submit((64 + i) as u64, p.clone());
        }
        svc.flush();
        // single-block GETs hit pages from both table versions
        let mut buf = [0u8; 64];
        for (pid, page) in [(0u64, &pages[0]), (64u64, &pages[0])] {
            for blk in [0usize, 31, 63] {
                let n = svc.read_block(pid, blk, &mut buf).unwrap();
                assert_eq!(&buf[..n], &page[blk * 64..(blk + 1) * 64], "page {pid} block {blk}");
            }
        }
        // single-block PUT on an old-version page, then read it back both
        // block-wise and page-wise
        let line = [0xC3u8; 64];
        svc.write_block(0, 7, &line).unwrap();
        let n = svc.read_block(0, 7, &mut buf).unwrap();
        assert_eq!(&buf[..n], &line[..]);
        let mut expect = pages[0].clone();
        expect[7 * 64..8 * 64].copy_from_slice(&line);
        assert_eq!(svc.read_page(0).unwrap(), expect);
        // errors are counted on the right side, latencies recorded
        assert!(svc.read_block(9999, 0, &mut buf).is_err());
        assert!(svc.write_block(9999, 0, &line).is_err());
        let m = svc.shutdown();
        assert!(m.block_reads >= 7);
        assert_eq!(m.block_writes, 1);
        assert!(m.block_read_mean_ns() > 0.0);
        assert!(m.block_write_mean_ns() > 0.0);
        assert_eq!(m.read_errors, 1);
        assert_eq!(m.write_errors, 1);
    }

    #[test]
    fn missing_page_read_errors() {
        let svc = service(1);
        assert!(svc.read_page(999).is_err());
        let m = svc.shutdown();
        assert_eq!(m.read_errors, 1);
    }

    #[test]
    fn batched_submit_matches_single_submits() {
        // submit_batch must be observationally identical to a stream of
        // single submits: same stored pages, same accounting
        let w = workloads::by_name("fluidanimate").unwrap();
        let pages: Vec<Vec<u8>> = (0..48).map(|i| w.generate(4096, i)).collect();
        let arm = |batched: bool| {
            let svc = CompressionService::start_static(
                ServiceConfig { workers: 2, shards: 4, ..Default::default() },
                Arc::new(crate::baselines::bdi::Bdi::default()),
            )
            .unwrap();
            if batched {
                svc.submit_batch(
                    pages.iter().enumerate().map(|(i, p)| (i as u64, p.clone())).collect(),
                );
            } else {
                for (i, p) in pages.iter().enumerate() {
                    svc.submit(i as u64, p.clone());
                }
            }
            svc.flush();
            for (i, p) in pages.iter().enumerate() {
                assert_eq!(&svc.read_page(i as u64).unwrap(), p, "batched={batched} page {i}");
            }
            let (logical, stored, _) = svc.storage_ratio();
            let m = svc.shutdown();
            (logical, stored, m.pages_in, m.bytes_in, m.bytes_out)
        };
        let single = arm(false);
        let batched = arm(true);
        assert_eq!(single, batched);
        // empty batches are a no-op and must not wedge flush
        let svc = service(1);
        svc.submit_batch(Vec::new());
        svc.flush();
        assert_eq!(svc.shutdown().pages_in, 0);
    }

    #[test]
    fn shard_metrics_sum_to_service_totals() {
        let svc = service(2); // default config: 8 shards
        assert_eq!(svc.shard_count(), 8);
        let w = workloads::by_name("mcf").unwrap();
        for i in 0..64u64 {
            svc.submit(i, w.generate(4096, i));
        }
        svc.flush();
        let mut line = [0u8; 64];
        for i in 0..64u64 {
            svc.read_block(i, (i % 64) as usize, &mut line).unwrap();
        }
        for i in 0..16u64 {
            svc.write_block(i, 3, &line).unwrap();
        }
        // failed ops are counted as errors, never as served block ops
        assert!(svc.read_block(9999, 0, &mut line).is_err());
        assert!(svc.write_block(9999, 0, &line).is_err());
        let shards = svc.shard_metrics();
        assert_eq!(shards.len(), 8);
        let m = svc.metrics();
        assert_eq!(shards.iter().map(|s| s.block_reads).sum::<u64>(), m.block_reads);
        assert_eq!(shards.iter().map(|s| s.block_writes).sum::<u64>(), m.block_writes);
        assert_eq!(m.block_reads, 64);
        assert_eq!(m.block_writes, 16);
        assert_eq!(shards.iter().map(|s| s.pages).sum::<u64>(), 64);
        assert_eq!(shards.iter().map(|s| s.logical_bytes).sum::<u64>(), 64 * 4096);
        assert_eq!(
            shards.iter().map(|s| s.stored_bytes).sum::<u64>(),
            svc.storage_ratio().1 as u64
        );
        // ingest really spread over multiple shards
        assert!(shards.iter().filter(|s| s.pages > 0).count() > 1);
        svc.shutdown();
    }

    #[test]
    fn cached_service_matches_cacheless_and_counts_every_block_op() {
        let w = workloads::by_name("mcf").unwrap();
        let pages: Vec<Vec<u8>> = (0..32).map(|i| w.generate(4096, i)).collect();
        let patch = [0xA5u8; 64];
        let arm = |cache_bytes: usize| {
            let svc = CompressionService::start_static(
                ServiceConfig { workers: 2, shards: 4, cache_bytes, ..Default::default() },
                Arc::new(crate::baselines::bdi::Bdi::default()),
            )
            .unwrap();
            svc.submit_batch(
                pages.iter().enumerate().map(|(i, p)| (i as u64, p.clone())).collect(),
            );
            svc.flush();
            // skewed block traffic: a small set of (page, block) pairs
            // re-referenced many times, plus repeated writes to one block
            let mut line = [0u8; 64];
            for round in 0..8u64 {
                for pid in 0..8u64 {
                    let n = svc.read_block(pid, (pid % 4) as usize, &mut line).unwrap();
                    assert_eq!(n, 64, "round {round} page {pid}");
                }
            }
            for _ in 0..4 {
                svc.write_block(3, 5, &patch).unwrap();
            }
            let flushed = svc.flush_cache();
            // page images after the dust settles (deferred or flushed,
            // the content must be the same)
            let mut out = Vec::new();
            let mut images = Vec::new();
            for i in 0..pages.len() as u64 {
                svc.read_page_into(i, &mut out).unwrap();
                images.push(out.clone());
            }
            let totals = svc.cache_totals();
            let shards = CacheTotals::from_shards(&svc.shard_metrics());
            assert_eq!(totals, shards, "service totals must equal shard sums");
            let m = svc.shutdown();
            (images, flushed, totals, m.block_reads + m.block_writes)
        };
        let (plain, plain_flushed, plain_totals, _) = arm(0);
        let (cached, cached_flushed, t, ops) = arm(1 << 20);
        assert_eq!(plain, cached, "cache must be observationally invisible");
        let mut expect = pages[3].clone();
        expect[5 * 64..6 * 64].copy_from_slice(&patch);
        assert_eq!(plain[3], expect, "block write visible in the page image");
        assert_eq!(plain_flushed, 0);
        assert_eq!(plain_totals, CacheTotals::default());
        // with the cache on, every successful block op is a hit or a miss
        assert_eq!(t.hits + t.misses, ops);
        assert!(t.hits > 0, "re-referenced blocks never hit: {t:?}");
        assert!(t.admissions > 0);
        // 3 of the 4 writes to (3, 5) were absorbed and deferred; the
        // explicit flush recompressed that one dirty block
        assert_eq!(cached_flushed, 1);
        assert_eq!(t.deferred_flushes, 1);
        assert_eq!(t.dirty_blocks, 0, "flush leaves the cache clean");
        assert!(t.cached_bytes > 0, "flushed blocks stay resident");
    }

    #[test]
    fn integrity_service_detects_quarantines_and_recovers_via_put() {
        let svc = CompressionService::start_static(
            ServiceConfig {
                workers: 1,
                shards: 2,
                integrity: IntegrityConfig { enabled: true, verify_reads: true, scrub_mib_s: 64 },
                ..Default::default()
            },
            Arc::new(crate::baselines::bdi::Bdi::default()),
        )
        .unwrap();
        let w = workloads::by_name("mcf").unwrap();
        for i in 0..8u64 {
            svc.submit(i, w.generate(4096, i));
        }
        svc.flush();
        // clean store: verified reads serve, the scrubber makes progress
        for i in 0..8u64 {
            assert_eq!(svc.read_page(i).unwrap(), w.generate(4096, i), "page {i}");
        }
        for _ in 0..400 {
            if svc.integrity_totals().scrubbed >= 8 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(svc.integrity_totals().scrubbed >= 8, "scrubber never covered the store");
        assert_eq!(svc.integrity_totals().corrupt_detected, 0);
        // flip one stored bit: whichever detector gets there first (the
        // scrubber or the next verified read) fences the page exactly once
        assert!(
            (0..64).any(|b| svc.corrupt_page_block(3, b, 1)),
            "no stored bits to corrupt"
        );
        let r = svc.read_page(3);
        assert!(matches!(r, Err(crate::Error::DataLoss(_))), "got {r:?}");
        let t = svc.integrity_totals();
        assert_eq!(t.corrupt_detected, 1);
        assert_eq!(t.quarantined, 1);
        assert_eq!(t.healed, 0, "no durable copy exists to heal from");
        assert_eq!(svc.quarantined_pages(), vec![3]);
        // unrelated pages keep serving
        assert_eq!(svc.read_page(2).unwrap(), w.generate(4096, 2));
        // a full-page overwrite supersedes the lost content and lifts
        // the fence
        svc.submit(3, w.generate(4096, 99));
        svc.flush();
        assert_eq!(svc.read_page(3).unwrap(), w.generate(4096, 99));
        assert!(svc.quarantined_pages().is_empty());
        svc.shutdown();
    }

    #[test]
    fn single_shard_service_still_serves() {
        // shards = 1 must reproduce the old single-lock behavior
        let svc = CompressionService::start(ServiceConfig {
            workers: 2,
            shards: 1,
            analyze_every: 16,
            ..Default::default()
        })
        .unwrap();
        let w = workloads::by_name("svm").unwrap();
        for i in 0..32u64 {
            svc.submit(i, w.generate(4096, i));
        }
        svc.flush();
        for i in 0..32u64 {
            assert_eq!(svc.read_page(i).unwrap(), w.generate(4096, i));
        }
        assert_eq!(svc.shard_count(), 1);
        let shards = svc.shard_metrics();
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].pages, 32);
        svc.shutdown();
    }
}
