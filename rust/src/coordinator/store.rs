//! Versioned compressed-page store: pages encoded under different codec
//! versions coexist; the codec ring keeps every published version so any
//! page stays decodable until migrated. Codec-agnostic: the ring holds
//! `Arc<dyn BlockCodec>` — GBDI tables are just one kind of versioned
//! codec state.
//!
//! Pages are stored as random-access [`Frame`]s, so the serving paths
//! are block-granular: [`PageStore::read_block`] decodes one cache line
//! out of a compressed page in O(1) without materializing the page, and
//! [`PageStore::write_block`] recompresses one line in place (spilling
//! to the frame's patch region when it grows) instead of round-tripping
//! the whole page.

use crate::codec::{BlockCodec, Scratch};
use crate::frame::{BlockWrite, Frame};
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// One stored page: a compressed random-access frame. The codec version
/// it references is the frame's codec's version.
pub struct StoredPage {
    /// The page's compressed form + block index.
    pub frame: Frame,
}

impl StoredPage {
    /// Codec version the payload references (GBDI: table version).
    pub fn codec_version(&self) -> u64 {
        self.frame.codec().version()
    }

    /// Original (logical) length in bytes.
    pub fn original_len(&self) -> usize {
        self.frame.len()
    }

    /// Compressed bytes including framing (payload + patches + index).
    pub fn stored_len(&self) -> usize {
        self.frame.compressed_len()
    }
}

/// The page store + codec ring.
#[derive(Default)]
pub struct PageStore {
    pages: HashMap<u64, StoredPage>,
    codecs: HashMap<u64, Arc<dyn BlockCodec>>,
    /// Reusable buffers for the block-granular write path.
    scratch: Scratch,
}

impl PageStore {
    /// Empty store.
    pub fn new() -> Self {
        PageStore::default()
    }

    /// Publish a codec version (idempotent; versions are immutable).
    pub fn publish_codec(&mut self, codec: Arc<dyn BlockCodec>) {
        self.codecs.entry(codec.version()).or_insert(codec);
    }

    /// Look up a published codec version.
    pub fn codec(&self, version: u64) -> Option<&Arc<dyn BlockCodec>> {
        self.codecs.get(&version)
    }

    /// Number of published codec versions.
    pub fn codec_count(&self) -> usize {
        self.codecs.len()
    }

    /// Insert/overwrite a page.
    pub fn put(&mut self, page_id: u64, page: StoredPage) {
        debug_assert!(
            self.codecs.contains_key(&page.codec_version()),
            "page references unpublished codec v{}",
            page.codec_version()
        );
        self.pages.insert(page_id, page);
    }

    /// Get a stored page.
    pub fn get(&self, page_id: u64) -> Option<&StoredPage> {
        self.pages.get(&page_id)
    }

    /// Remove a page (returns it).
    pub fn remove(&mut self, page_id: u64) -> Option<StoredPage> {
        self.pages.remove(&page_id)
    }

    /// Number of stored pages.
    pub fn len(&self) -> usize {
        self.pages.len()
    }

    /// Whether the store is empty.
    pub fn is_empty(&self) -> bool {
        self.pages.is_empty()
    }

    /// Total compressed bytes stored.
    pub fn stored_bytes(&self) -> usize {
        self.pages.values().map(|p| p.stored_len()).sum()
    }

    /// Total logical bytes stored.
    pub fn logical_bytes(&self) -> usize {
        self.pages.values().map(|p| p.original_len()).sum()
    }

    /// Ids of pages encoded with a version older than `version`.
    pub fn lagging_pages(&self, version: u64) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .pages
            .iter()
            .filter(|(_, p)| p.codec_version() < version)
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        ids
    }

    fn page(&self, page_id: u64) -> Result<&StoredPage> {
        self.pages
            .get(&page_id)
            .ok_or_else(|| Error::Corrupt(format!("page {page_id} not found")))
    }

    /// Decompress a whole page (each frame carries its own codec, so
    /// any published version decodes).
    pub fn read(&self, page_id: u64) -> Result<Vec<u8>> {
        self.page(page_id)?.frame.decompress()
    }

    /// Decode one block of a page into `out[..len]`; returns the bytes
    /// written. O(1) in the page size, allocation-free.
    pub fn read_block(&self, page_id: u64, block: usize, out: &mut [u8]) -> Result<usize> {
        self.page(page_id)?.frame.read_block(block, out)
    }

    /// Recompress one block of a page in place from `data` (exactly the
    /// block's logical length). Spilled writes accumulate patch-region
    /// garbage; once a page's patch bytes exceed half its footprint the
    /// frame is compacted, so storage accounting stays bounded under
    /// sustained write traffic.
    pub fn write_block(&mut self, page_id: u64, block: usize, data: &[u8]) -> Result<BlockWrite> {
        let page = self
            .pages
            .get_mut(&page_id)
            .ok_or_else(|| Error::Corrupt(format!("page {page_id} not found")))?;
        let wr = page.frame.write_block(block, data, &mut self.scratch)?;
        if page.frame.patch_len() * 2 > page.frame.compressed_len() {
            page.frame.compact();
        }
        Ok(wr)
    }

    /// Drop codec versions no page references anymore (except the newest
    /// `keep` versions). Returns how many were dropped.
    pub fn gc_codecs(&mut self, keep: usize) -> usize {
        let referenced: std::collections::BTreeSet<u64> =
            self.pages.values().map(|p| p.codec_version()).collect();
        let mut versions: Vec<u64> = self.codecs.keys().copied().collect();
        versions.sort_unstable();
        let keep_from = versions.len().saturating_sub(keep);
        let mut dropped = 0;
        for (i, v) in versions.into_iter().enumerate() {
            if i < keep_from && !referenced.contains(&v) {
                self.codecs.remove(&v);
                dropped += 1;
            }
        }
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdi::{analyze, table::GlobalBaseTable, GbdiCodec, GbdiConfig};
    use crate::value::WordSize;
    use crate::workloads;

    fn compress_page(data: &[u8], codec: &Arc<dyn BlockCodec>) -> StoredPage {
        StoredPage { frame: Frame::compress(Arc::clone(codec), data) }
    }

    #[test]
    fn pages_survive_codec_swaps() {
        let cfg = GbdiConfig::default();
        let img_a = workloads::by_name("mcf").unwrap().generate(4096, 1);
        let img_b = workloads::by_name("svm").unwrap().generate(4096, 1);
        let mut t1 = analyze::analyze_image(&img_a, &cfg);
        t1.version = 1;
        let mut t2 = analyze::analyze_image(&img_b, &cfg);
        t2.version = 2;
        let c1: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t1, cfg.clone()));
        let c2: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t2, cfg.clone()));

        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&c1));
        store.put(10, compress_page(&img_a, &c1));
        store.publish_codec(Arc::clone(&c2));
        store.put(20, compress_page(&img_b, &c2));

        // both decode bit-exactly despite different codec versions
        assert_eq!(store.read(10).unwrap(), img_a);
        assert_eq!(store.read(20).unwrap(), img_b);
        assert_eq!(store.lagging_pages(2), vec![10]);
        assert_eq!(store.lagging_pages(1), Vec::<u64>::new());
    }

    #[test]
    fn block_reads_and_writes_hit_frames_not_pages() {
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("mcf").unwrap().generate(4096, 9);
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        // single-block GET matches the image slice
        let mut buf = [0u8; 64];
        for i in [0usize, 7, 63] {
            let n = store.read_block(1, i, &mut buf).unwrap();
            assert_eq!(&buf[..n], &img[i * 64..(i + 1) * 64]);
        }
        // single-block PUT is visible to both block and page reads
        let line = [0x5Au8; 64];
        store.write_block(1, 5, &line).unwrap();
        let n = store.read_block(1, 5, &mut buf).unwrap();
        assert_eq!(&buf[..n], &line[..]);
        let mut expect = img.clone();
        expect[5 * 64..6 * 64].copy_from_slice(&line);
        assert_eq!(store.read(1).unwrap(), expect);
        // out-of-range accesses error
        assert!(store.read_block(1, 64, &mut buf).is_err());
        assert!(store.read_block(99, 0, &mut buf).is_err());
        assert!(store.write_block(99, 0, &line).is_err());
    }

    #[test]
    fn sustained_block_writes_keep_storage_bounded() {
        // growth-spill garbage must not accumulate without bound: the
        // store compacts a frame once patch bytes dominate its footprint
        let cfg = GbdiConfig::default();
        let img = vec![0u8; 4096];
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        let mut rng = crate::util::prng::Rng::new(5);
        let mut noisy = [0u8; 64];
        let mut expect = img.clone();
        for round in 0..200 {
            let blk = (round * 7) % 64;
            if round % 3 == 2 {
                noisy[..].fill(0);
            } else {
                rng.fill_bytes(&mut noisy);
            }
            store.write_block(1, blk, &noisy).unwrap();
            expect[blk * 64..(blk + 1) * 64].copy_from_slice(&noisy);
        }
        // bound: the page never stores more than ~2x its worst-case raw
        // footprint (64 raw blocks + framing), however many spills happened
        let stored = store.get(1).unwrap().stored_len();
        assert!(stored < 2 * (4096 + 4096 / 64 * 3 + 16), "stored {stored} B unbounded");
        assert_eq!(store.read(1).unwrap(), expect, "content survives compactions");
    }

    #[test]
    fn heterogeneous_codecs_coexist() {
        // the ring is codec-agnostic: a BDI page (version 0) and a GBDI
        // page (version 3) live side by side
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("fluidanimate").unwrap().generate(4096, 2);
        let bdi: Arc<dyn BlockCodec> =
            Arc::new(crate::baselines::bdi::Bdi { block_bytes: cfg.block_bytes });
        let mut t = analyze::analyze_image(&img, &cfg);
        t.version = 3;
        let gbdi: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t, cfg));

        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&bdi));
        store.put(1, compress_page(&img, &bdi));
        store.publish_codec(Arc::clone(&gbdi));
        store.put(2, compress_page(&img, &gbdi));
        assert_eq!(store.read(1).unwrap(), img);
        assert_eq!(store.read(2).unwrap(), img);
        assert_eq!(store.codec_count(), 2);
    }

    #[test]
    fn missing_page_and_codec_error() {
        let store = PageStore::new();
        assert!(store.read(99).is_err());
    }

    #[test]
    fn gc_keeps_referenced_versions() {
        let cfg = GbdiConfig::default();
        let img = vec![7u8; 4096];
        let mut store = PageStore::new();
        for v in 1..=5 {
            let t = GlobalBaseTable::new(vec![(v * 1000, 8)], WordSize::W32, v);
            let codec: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t, cfg.clone()));
            store.publish_codec(Arc::clone(&codec));
            if v == 2 {
                store.put(1, compress_page(&img, &codec));
            }
        }
        let dropped = store.gc_codecs(1);
        // v1, v3, v4 droppable; v2 referenced; v5 newest kept
        assert_eq!(dropped, 3);
        assert!(store.codec(2).is_some());
        assert!(store.codec(5).is_some());
        assert_eq!(store.read(1).unwrap(), img);
    }

    #[test]
    fn accounting() {
        let cfg = GbdiConfig::default();
        let img = vec![0u8; 8192];
        let t = analyze::analyze_image(&img, &cfg);
        let codec: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t, cfg));
        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        assert_eq!(store.len(), 1);
        assert_eq!(store.logical_bytes(), 8192);
        assert!(store.stored_bytes() < 2048, "zeros compress: {}", store.stored_bytes());
        store.remove(1).unwrap();
        assert!(store.is_empty());
    }
}
