//! Versioned compressed-page store: pages encoded under different codec
//! versions coexist; the codec ring keeps every published version so any
//! page stays decodable until migrated. Codec-agnostic: the ring holds
//! `Arc<dyn BlockCodec>` — GBDI tables are just one kind of versioned
//! codec state.
//!
//! Pages are stored as random-access [`Frame`]s, so the serving paths
//! are block-granular: [`PageStore::read_block`] decodes one cache line
//! out of a compressed page in O(1) without materializing the page, and
//! [`PageStore::write_block`] recompresses one line in place (spilling
//! to the frame's patch region when it grows) instead of round-tripping
//! the whole page.
//!
//! Two stores live here (DESIGN.md §8):
//!
//! * [`PageStore`] — the plain single-owner store: no interior locking,
//!   `&mut self` writes. It is the *reference semantics* — the sharded
//!   store must be observationally identical to it under any
//!   single-threaded interleaving of operations
//!   (`tests/sharded_store.rs` enforces this for N ∈ {1, 2, 7}).
//! * [`ShardedPageStore`] — N independently locked shards routed by a
//!   page-id hash, each with its own [`Scratch`] and
//!   [`ShardMetrics`](super::metrics::ShardMetrics), sharing **one**
//!   codec ring behind its own lock so publishing a new table version
//!   is a single O(1) insert, not an O(shards) fan-out. All methods are
//!   `&self`: callers on different shards never contend.
//!
//! The sharded store can additionally carry a **hot-block cache tier**
//! ([`Self::with_cache`](ShardedPageStore::with_cache)): one bounded
//! S3-FIFO [`BlockCache`](super::cache::BlockCache) per shard, serving
//! block-read hits straight from uncompressed memory and absorbing
//! block writes to resident blocks as *deferred recompressions* — the
//! dirty block stays uncompressed until it cools out of the cache (or
//! its page is removed/migrated), and only then goes back through the
//! normal [`Frame::write_block`] path. Lock order is fixed: a shard's
//! cache mutex is always acquired *before* its state lock, so eviction
//! flushes can take the state lock without deadlocking. With the cache
//! off (the default), every code path is byte-identical to before.
//!
//! An optional **integrity plane**
//! ([`ShardedPageStore::with_integrity`], DESIGN.md §13) keeps one
//! CRC-32 digest per page beside the frames — maintained
//! *incrementally* on block writes (`crc ^= old_term ^ new_term`,
//! O(block)) so the hot path never re-hashes a page — and fences pages
//! whose digest stops matching: quarantined pages answer every read
//! and write with [`Error::DataLoss`] until
//! [`ShardedPageStore::heal_page`] installs a verified copy recovered
//! from durable state. With integrity off (the default), the side maps
//! stay empty and every code path is byte-identical to before.

use super::cache::{BlockCache, EvictedBlock};
use super::metrics::{
    CacheGauges, CacheTotals, IntegrityTotals, ShardMetrics, ShardMetricsSnapshot,
};
use crate::codec::{BlockCodec, Scratch};
use crate::frame::{BlockWrite, Frame};
use crate::{Error, Result};
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// One stored page: a compressed random-access frame. The codec version
/// it references is the frame's codec's version.
pub struct StoredPage {
    /// The page's compressed form + block index.
    pub frame: Frame,
}

impl StoredPage {
    /// Codec version the payload references (GBDI: table version).
    pub fn codec_version(&self) -> u64 {
        self.frame.codec().version()
    }

    /// Original (logical) length in bytes.
    pub fn original_len(&self) -> usize {
        self.frame.len()
    }

    /// Compressed bytes including framing (payload + patches + index).
    pub fn stored_len(&self) -> usize {
        self.frame.compressed_len()
    }
}

/// The page store + codec ring.
#[derive(Default)]
pub struct PageStore {
    pages: HashMap<u64, StoredPage>,
    codecs: HashMap<u64, Arc<dyn BlockCodec>>,
    /// Reusable buffers for the block-granular write path.
    scratch: Scratch,
}

impl PageStore {
    /// Empty store.
    pub fn new() -> Self {
        PageStore::default()
    }

    /// Publish a codec version (idempotent; versions are immutable).
    pub fn publish_codec(&mut self, codec: Arc<dyn BlockCodec>) {
        self.codecs.entry(codec.version()).or_insert(codec);
    }

    /// Look up a published codec version.
    pub fn codec(&self, version: u64) -> Option<&Arc<dyn BlockCodec>> {
        self.codecs.get(&version)
    }

    /// Number of published codec versions.
    pub fn codec_count(&self) -> usize {
        self.codecs.len()
    }

    /// Insert/overwrite a page.
    pub fn put(&mut self, page_id: u64, page: StoredPage) {
        debug_assert!(
            self.codecs.contains_key(&page.codec_version()),
            "page references unpublished codec v{}",
            page.codec_version()
        );
        self.pages.insert(page_id, page);
    }

    /// Get a stored page.
    pub fn get(&self, page_id: u64) -> Option<&StoredPage> {
        self.pages.get(&page_id)
    }

    /// Remove a page (returns it).
    pub fn remove(&mut self, page_id: u64) -> Option<StoredPage> {
        self.pages.remove(&page_id)
    }

    /// Number of stored pages.
    pub fn len(&self) -> usize {
        self.pages.len()
    }

    /// Whether the store is empty.
    pub fn is_empty(&self) -> bool {
        self.pages.is_empty()
    }

    /// Total compressed bytes stored.
    pub fn stored_bytes(&self) -> usize {
        self.pages.values().map(|p| p.stored_len()).sum()
    }

    /// Total logical bytes stored.
    pub fn logical_bytes(&self) -> usize {
        self.pages.values().map(|p| p.original_len()).sum()
    }

    /// Ids of pages encoded with a version older than `version`.
    pub fn lagging_pages(&self, version: u64) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .pages
            .iter()
            .filter(|(_, p)| p.codec_version() < version)
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        ids
    }

    fn page(&self, page_id: u64) -> Result<&StoredPage> {
        self.pages
            .get(&page_id)
            .ok_or_else(|| Error::Corrupt(format!("page {page_id} not found")))
    }

    /// Decompress a whole page (each frame carries its own codec, so
    /// any published version decodes).
    pub fn read(&self, page_id: u64) -> Result<Vec<u8>> {
        self.page(page_id)?.frame.decompress()
    }

    /// Decompress a whole page into `out`, reusing its allocation — the
    /// zero-allocation loop shape for page sweeps
    /// (`tests/alloc_counting.rs` pins it).
    pub fn read_into(&self, page_id: u64, out: &mut Vec<u8>) -> Result<()> {
        self.page(page_id)?.frame.decompress_into(out)
    }

    /// Decode one block of a page into `out[..len]`; returns the bytes
    /// written. O(1) in the page size, allocation-free.
    pub fn read_block(&self, page_id: u64, block: usize, out: &mut [u8]) -> Result<usize> {
        self.page(page_id)?.frame.read_block(block, out)
    }

    /// Recompress one block of a page in place from `data` (exactly the
    /// block's logical length). Spilled writes accumulate patch-region
    /// garbage; once a page's patch bytes exceed half its footprint the
    /// frame is compacted, so storage accounting stays bounded under
    /// sustained write traffic.
    pub fn write_block(&mut self, page_id: u64, block: usize, data: &[u8]) -> Result<BlockWrite> {
        let page = self
            .pages
            .get_mut(&page_id)
            .ok_or_else(|| Error::Corrupt(format!("page {page_id} not found")))?;
        let wr = page.frame.write_block(block, data, &mut self.scratch)?;
        if page.frame.patch_len() * 2 > page.frame.compressed_len() {
            page.frame.compact();
        }
        Ok(wr)
    }

    /// Drop codec versions no page references anymore (except the newest
    /// `keep` versions). Returns how many were dropped.
    pub fn gc_codecs(&mut self, keep: usize) -> usize {
        let referenced: std::collections::BTreeSet<u64> =
            self.pages.values().map(|p| p.codec_version()).collect();
        let mut versions: Vec<u64> = self.codecs.keys().copied().collect();
        versions.sort_unstable();
        let keep_from = versions.len().saturating_sub(keep);
        let mut dropped = 0;
        for (i, v) in versions.into_iter().enumerate() {
            if i < keep_from && !referenced.contains(&v) {
                self.codecs.remove(&v);
                dropped += 1;
            }
        }
        dropped
    }
}

/// One shard's mutable state: its slice of the page map plus the
/// scratch buffers the block-write path reuses under the shard lock,
/// plus the integrity side state (both maps stay empty with the
/// integrity plane off, so the presence of a `crcs` entry is itself
/// the per-page "digest is maintained" gate).
struct PageShard {
    pages: HashMap<u64, StoredPage>,
    scratch: Scratch,
    /// page id -> CRC-32 digest of the page's compressed image
    /// ([`Frame::image_crc`]), kept current by every frame mutation.
    crcs: HashMap<u64, u32>,
    /// Pages whose digest failed verification: fenced from every read
    /// and write until healed, overwritten, or removed.
    quarantined: HashSet<u64>,
}

impl Default for PageShard {
    fn default() -> Self {
        PageShard {
            pages: HashMap::new(),
            scratch: Scratch::new(),
            crcs: HashMap::new(),
            quarantined: HashSet::new(),
        }
    }
}

/// Integrity-plane configuration (DESIGN.md §13). Off by default — the
/// store then keeps no digests and every path behaves exactly as
/// before.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IntegrityConfig {
    /// Maintain per-page digests and fence pages that fail them.
    pub enabled: bool,
    /// Verify a page's digest on the read paths before serving from its
    /// compressed frame (whole-page reads *and* block-read decode
    /// misses). Strong "never serve silently-wrong data" mode; costs an
    /// O(page) hash per frame decode, quantified by the
    /// `concurrent_serving` bench's integrity arm. With this off,
    /// detection falls to the background scrubber.
    pub verify_reads: bool,
    /// Background scrub budget in MiB/s of compressed image re-hashed
    /// (0 disables the scrubber thread).
    pub scrub_mib_s: u64,
}

impl Default for IntegrityConfig {
    fn default() -> Self {
        IntegrityConfig { enabled: false, verify_reads: true, scrub_mib_s: 8 }
    }
}

/// What [`ShardedPageStore::scrub_page`] found. `bytes` is the
/// compressed image size hashed, which the scrubber counts against its
/// bytes/sec budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScrubOutcome {
    /// Digest verified clean.
    Clean {
        /// Compressed bytes hashed.
        bytes: usize,
    },
    /// Digest mismatch confirmed under the exclusive lock: the page is
    /// now quarantined.
    Corrupt {
        /// Compressed bytes hashed.
        bytes: usize,
    },
    /// Nothing to verify: integrity off, page missing, already
    /// quarantined, or a racing write refreshed the digest.
    Skipped,
}

/// The standard fence error a quarantined page answers with.
fn data_loss(page_id: u64) -> Error {
    Error::DataLoss(format!("page {page_id} failed integrity verification and is quarantined"))
}

/// The CRC term `block` contributes to its page's image digest right
/// now, or 0 when the page carries no digest / the block is out of
/// range. Captured *before* a frame mutation and XORed back out by
/// [`fold_crc`].
fn crc_term(crcs: &HashMap<u64, u32>, id: u64, frame: &Frame, block: usize) -> u32 {
    if crcs.contains_key(&id) && block < frame.n_blocks() {
        frame.block_crc(block)
    } else {
        0
    }
}

/// Fold one block's digest delta into its page's image CRC — the
/// O(block) incremental update (DESIGN.md §13): `crc ^= old_term ^
/// new_term`. A no-op when the page carries no digest, and also when
/// the mutation failed without touching the frame (old and new terms
/// cancel).
fn fold_crc(crcs: &mut HashMap<u64, u32>, id: u64, old_term: u32, frame: &Frame, block: usize) {
    if let Some(crc) = crcs.get_mut(&id) {
        let new_term = if block < frame.n_blocks() { frame.block_crc(block) } else { 0 };
        *crc ^= old_term ^ new_term;
    }
}

/// A shard: independently locked state + its hot-path counters, plus an
/// optional hot-block cache. The cache sits behind its own mutex,
/// acquired strictly *before* the state lock — the eviction path holds
/// the cache mutex while flushing deferred writes under the state lock.
struct Shard {
    state: RwLock<PageShard>,
    metrics: ShardMetrics,
    cache: Option<Mutex<BlockCache>>,
}

/// The concurrent page store: N independently locked shards with
/// page-id hash routing, sharing one codec ring (DESIGN.md §8).
///
/// Every method takes `&self`: operations on pages in different shards
/// run fully in parallel, readers of the same shard run in parallel
/// (per-shard `RwLock`), and only writers to the *same shard* serialize.
/// The codec ring sits behind its own lock, so publishing a swapped-in
/// table version is one O(1) insert — shards read codecs through the
/// shared `Arc`s and never copy the ring.
///
/// Semantics are observationally identical to [`PageStore`] (same
/// compaction policy, same error surface); `tests/sharded_store.rs`
/// pins the equivalence under randomized operation interleavings for
/// N ∈ {1, 2, 7}.
///
/// ```
/// use gbdi::coordinator::{ShardedPageStore, StoredPage};
/// use gbdi::{BlockCodec, CodecKind, Frame, GbdiConfig};
/// use std::sync::Arc;
///
/// let image = vec![0u8; 4096];
/// let codec: Arc<dyn BlockCodec> =
///     Arc::from(CodecKind::Gbdi.build_for_image(&image, &GbdiConfig::default()));
/// let store = ShardedPageStore::new(4);
/// store.publish_codec(Arc::clone(&codec));
/// store.put(7, StoredPage { frame: Frame::compress(Arc::clone(&codec), &image) });
/// assert_eq!(store.read(7).unwrap(), image);
/// let mut line = [0u8; 64];
/// store.write_block(7, 3, &[9u8; 64]).unwrap();
/// assert_eq!(store.read_block(7, 3, &mut line).unwrap(), 64);
/// assert_eq!(line, [9u8; 64]);
/// ```
pub struct ShardedPageStore {
    /// The shard set sits behind one outer `RwLock` so
    /// [`Self::resize_shards`] can swap the topology online: every
    /// operation takes the read side for its duration (uncontended in
    /// steady state), a resize takes the write side and so runs exactly
    /// when no operation is in flight. Inside the guard, routing uses
    /// [`Self::route`] with the guard's own length — never a re-entrant
    /// read acquisition, which could deadlock behind a queued resize.
    shards: RwLock<Vec<Shard>>,
    codecs: RwLock<HashMap<u64, Arc<dyn BlockCodec>>>,
    /// Compact a frame once its patch region dominates its footprint
    /// (the serving default). The memory simulator opts out: compaction
    /// rebuilds frames *tight*, which would silently discard the
    /// sector-alignment slack its hardware model depends on.
    auto_compact: bool,
    /// Total cache budget [`Self::with_cache`] was given — remembered so
    /// a resize can re-split it across the new shard count.
    cache_bytes: usize,
    /// Integrity-plane configuration; `None` = off (the default), and
    /// the per-shard digest maps then stay empty.
    integrity: Option<IntegrityConfig>,
}

impl ShardedPageStore {
    /// Empty store with `shards` shards (clamped to at least 1). The
    /// hot-block cache is off; opt in with [`Self::with_cache`].
    pub fn new(shards: usize) -> Self {
        ShardedPageStore {
            shards: RwLock::new(
                (0..shards.max(1))
                    .map(|_| Shard {
                        state: RwLock::new(PageShard::default()),
                        metrics: ShardMetrics::new(),
                        cache: None,
                    })
                    .collect(),
            ),
            codecs: RwLock::new(HashMap::new()),
            auto_compact: true,
            cache_bytes: 0,
            integrity: None,
        }
    }

    /// Disable the automatic patch-compaction policy (consuming
    /// builder; call at construction, before the store is shared).
    /// Writes then never rebuild a frame's layout behind the caller's
    /// back — the memory simulator uses this to keep its sector-aligned
    /// spans intact, at the cost of unbounded patch growth under
    /// sustained writes.
    pub fn without_auto_compact(mut self) -> Self {
        self.auto_compact = false;
        self
    }

    /// Attach a hot-block cache tier of `total_bytes`, split evenly
    /// across the shards (consuming builder; call at construction,
    /// before the store is shared). `0` leaves the cache off — every
    /// code path then behaves byte-identically to a cacheless store.
    pub fn with_cache(mut self, total_bytes: usize) -> Self {
        self.cache_bytes = total_bytes;
        let shards = self.shards.get_mut().unwrap();
        let n = shards.len();
        for shard in shards.iter_mut() {
            shard.cache = if total_bytes == 0 {
                None
            } else {
                // clamp so even a tiny budget holds at least a few
                // 64-byte blocks per shard instead of thrashing
                Some(Mutex::new(BlockCache::new((total_bytes / n).max(256))))
            };
        }
        self
    }

    /// Whether the hot-block cache tier is on.
    pub fn cache_enabled(&self) -> bool {
        self.cache_bytes > 0
    }

    /// Turn on the integrity plane (consuming builder; call at
    /// construction, before the store is shared). Computes a digest for
    /// every page already resident — a store recovered from durable
    /// state starts fully covered, not just pages written afterwards.
    /// A config with `enabled: false` leaves the plane off.
    pub fn with_integrity(mut self, cfg: IntegrityConfig) -> Self {
        if !cfg.enabled {
            self.integrity = None;
            return self;
        }
        for shard in self.shards.get_mut().unwrap().iter_mut() {
            let state = shard.state.get_mut().unwrap();
            let PageShard { pages, crcs, .. } = state;
            crcs.clear();
            for (&id, p) in pages.iter() {
                crcs.insert(id, p.frame.image_crc());
            }
        }
        self.integrity = Some(cfg);
        self
    }

    /// Whether the integrity plane is on.
    pub fn integrity_enabled(&self) -> bool {
        self.integrity.is_some()
    }

    /// The active integrity configuration (`None` = off) — the
    /// service's scrubber reads its budget from here.
    pub fn integrity_config(&self) -> Option<&IntegrityConfig> {
        self.integrity.as_ref()
    }

    /// Whether read paths verify digests before serving from frames.
    fn verify_reads(&self) -> bool {
        self.integrity.as_ref().is_some_and(|i| i.verify_reads)
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.read().unwrap().len()
    }

    /// Which shard of `n` a page id routes to: a Fibonacci
    /// multiplicative hash so dense sequential ids still spread evenly,
    /// reduced mod N (N need not be a power of two). Internal code calls
    /// this with the length of an already-held shards guard; re-entering
    /// [`Self::shard_of`] under a guard could deadlock behind a queued
    /// resize.
    fn route(page_id: u64, n: usize) -> usize {
        ((page_id.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) % n as u64) as usize
    }

    /// Which shard a page id routes to under the current topology.
    pub fn shard_of(&self, page_id: u64) -> usize {
        Self::route(page_id, self.shards.read().unwrap().len())
    }

    // ---- codec ring ------------------------------------------------------

    /// Publish a codec version (idempotent; versions are immutable). One
    /// O(1) insert into the shared ring — never an O(shards) fan-out.
    pub fn publish_codec(&self, codec: Arc<dyn BlockCodec>) {
        self.codecs.write().unwrap().entry(codec.version()).or_insert(codec);
    }

    /// Look up a published codec version (cloned `Arc`).
    pub fn codec(&self, version: u64) -> Option<Arc<dyn BlockCodec>> {
        self.codecs.read().unwrap().get(&version).cloned()
    }

    /// Number of published codec versions.
    pub fn codec_count(&self) -> usize {
        self.codecs.read().unwrap().len()
    }

    /// Drop codec versions no page references anymore (except the newest
    /// `keep` versions). Returns how many were dropped. Safe even if a
    /// racing `put` lands a page under an old version: frames carry
    /// their own codec `Arc`, so decode never depends on ring membership.
    pub fn gc_codecs(&self, keep: usize) -> usize {
        let mut referenced = std::collections::BTreeSet::new();
        let shards = self.shards.read().unwrap();
        for shard in shards.iter() {
            let state = shard.state.read().unwrap();
            referenced.extend(state.pages.values().map(|p| p.codec_version()));
        }
        drop(shards);
        let mut ring = self.codecs.write().unwrap();
        let mut versions: Vec<u64> = ring.keys().copied().collect();
        versions.sort_unstable();
        let keep_from = versions.len().saturating_sub(keep);
        let mut dropped = 0;
        for (i, v) in versions.into_iter().enumerate() {
            if i < keep_from && !referenced.contains(&v) {
                ring.remove(&v);
                dropped += 1;
            }
        }
        dropped
    }

    // ---- writes ----------------------------------------------------------

    /// Insert/overwrite a page (one exclusive acquisition of its shard).
    /// Overwriting drops any cached blocks of the page — including
    /// deferred writes, which the fresh page image supersedes.
    pub fn put(&self, page_id: u64, page: StoredPage) {
        debug_assert!(
            self.codecs.read().unwrap().contains_key(&page.codec_version()),
            "page references unpublished codec v{}",
            page.codec_version()
        );
        // hash the fresh image before taking any lock: O(page) work the
        // shard must not serialize behind
        let crc = self.integrity.as_ref().map(|_| page.frame.image_crc());
        let shards = self.shards.read().unwrap();
        let shard = &shards[Self::route(page_id, shards.len())];
        let mut cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
        let mut state = shard.state.write().unwrap();
        let t0 = Instant::now();
        if let Some(cache) = cache.as_deref_mut() {
            cache.invalidate_page(page_id);
        }
        if let Some(crc) = crc {
            state.crcs.insert(page_id, crc);
            // a full-page overwrite supersedes lost content entirely
            state.quarantined.remove(&page_id);
        }
        state.pages.insert(page_id, page);
        shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
    }

    /// Insert a batch of pages, grouping them per shard so each shard's
    /// lock is taken **once per batch** instead of once per page — the
    /// ingest path the batched submit feeds.
    pub fn put_batch(&self, pages: Vec<(u64, StoredPage)>) {
        #[cfg(debug_assertions)]
        {
            let ring = self.codecs.read().unwrap();
            for (_, p) in &pages {
                debug_assert!(
                    ring.contains_key(&p.codec_version()),
                    "page references unpublished codec v{}",
                    p.codec_version()
                );
            }
        }
        let shards = self.shards.read().unwrap();
        let n = shards.len();
        // digests are hashed here, outside every shard lock
        let mut by_shard: Vec<Vec<(u64, Option<u32>, StoredPage)>> =
            (0..n).map(|_| Vec::new()).collect();
        for (id, page) in pages {
            let crc = self.integrity.as_ref().map(|_| page.frame.image_crc());
            by_shard[Self::route(id, n)].push((id, crc, page));
        }
        for (idx, group) in by_shard.into_iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let shard = &shards[idx];
            let mut cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
            let mut state = shard.state.write().unwrap();
            let t0 = Instant::now();
            for (id, crc, page) in group {
                if let Some(cache) = cache.as_deref_mut() {
                    cache.invalidate_page(id);
                }
                if let Some(crc) = crc {
                    state.crcs.insert(id, crc);
                    state.quarantined.remove(&id);
                }
                state.pages.insert(id, page);
            }
            shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
        }
    }

    /// Remove a page (returns it). Deferred cached writes are folded
    /// into the page first, so the caller receives the latest content;
    /// all cached blocks of the page are dropped.
    pub fn remove(&self, page_id: u64) -> Option<StoredPage> {
        let shards = self.shards.read().unwrap();
        let shard = &shards[Self::route(page_id, shards.len())];
        let mut cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
        let mut state = shard.state.write().unwrap();
        let t0 = Instant::now();
        if let Some(cache) = cache.as_deref_mut() {
            let dirty = cache.dirty_blocks_of_page(page_id);
            if !dirty.is_empty() {
                let PageShard { pages, scratch, .. } = &mut *state;
                if let Some(page) = pages.get_mut(&page_id) {
                    for b in &dirty {
                        if let Some(data) = cache.data_of((page_id, *b)) {
                            // cached blocks always index valid blocks of
                            // a live frame, so this cannot fail; a
                            // corrupt frame surfaces on the next read
                            let _ = page.frame.write_block(*b as usize, data, scratch);
                        }
                    }
                    shard.metrics.deferred_flushed(dirty.len() as u64);
                }
            }
            cache.invalidate_page(page_id);
        }
        state.crcs.remove(&page_id);
        state.quarantined.remove(&page_id);
        let removed = state.pages.remove(&page_id);
        shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
        removed
    }

    /// Recompress one block of a page in place from `data` (exactly the
    /// block's logical length), under this shard's lock with its own
    /// scratch. Same compaction policy as [`PageStore::write_block`]
    /// unless disabled via [`Self::without_auto_compact`]: once patch
    /// bytes exceed half the frame's footprint it compacts, so storage
    /// stays bounded under sustained write traffic.
    pub fn write_block(&self, page_id: u64, block: usize, data: &[u8]) -> Result<BlockWrite> {
        self.write_block_observed(page_id, block, data).map(|(_, wr)| wr)
    }

    /// [`Self::write_block`] that also reports the block's encoded bits
    /// *before* the write, all under one lock acquisition — the memory
    /// simulator's sector accounting needs the before/after pair and
    /// must not pay two shard lookups per simulated write.
    pub fn write_block_observed(
        &self,
        page_id: u64,
        block: usize,
        data: &[u8],
    ) -> Result<(u32, BlockWrite)> {
        let shards = self.shards.read().unwrap();
        let shard = &shards[Self::route(page_id, shards.len())];
        let t0 = Instant::now();
        let r = match &shard.cache {
            None => self.write_block_frame(shard, page_id, block, data),
            Some(cache) => self.write_block_via_cache(shard, cache, page_id, block, data),
        };
        if r.is_ok() {
            shard.metrics.block_write(t0.elapsed().as_nanos() as u64);
        }
        r
    }

    /// The cacheless write path: recompress the block in the frame
    /// under the shard's exclusive lock (records lock-hold time, not the
    /// block-write counter — the caller owns that).
    fn write_block_frame(
        &self,
        shard: &Shard,
        page_id: u64,
        block: usize,
        data: &[u8],
    ) -> Result<(u32, BlockWrite)> {
        let mut state = shard.state.write().unwrap();
        let held = Instant::now();
        let r = {
            let PageShard { pages, scratch, crcs, quarantined } = &mut *state;
            if quarantined.contains(&page_id) {
                // building a partial write on corrupt content would
                // launder the corruption; the page must be healed or
                // fully overwritten first
                Err(data_loss(page_id))
            } else {
                match pages.get_mut(&page_id) {
                    Some(page) => {
                        // out-of-range blocks fall through to the
                        // frame's own range error below
                        let old = if block < page.frame.n_blocks() {
                            page.frame.block_bits(block)
                        } else {
                            0
                        };
                        let old_term = crc_term(crcs, page_id, &page.frame, block);
                        let wr = page.frame.write_block(block, data, scratch);
                        if wr.is_ok() {
                            fold_crc(crcs, page_id, old_term, &page.frame, block);
                            if self.auto_compact
                                && page.frame.patch_len() * 2 > page.frame.compressed_len()
                            {
                                // compaction relocates slots without
                                // changing any block's logical bits, so
                                // the digest is invariant (frame.rs
                                // pins this)
                                page.frame.compact();
                            }
                        }
                        wr.map(|wr| (old, wr))
                    }
                    None => Err(Error::Corrupt(format!("page {page_id} not found"))),
                }
            }
        };
        shard.metrics.lock_hold(held.elapsed().as_nanos() as u64);
        r
    }

    /// The cached write path. A write to a *resident* block is absorbed:
    /// the cached copy is updated and marked dirty, the frame keeps its
    /// stale encoding until the block cools out of the cache (deferred
    /// recompression), and the reported [`BlockWrite`] carries the
    /// frame's current bits with `spilled: false` — no framing changed.
    /// A write to a cold block goes through the frame as usual, then the
    /// fresh copy is admitted so a write-hot block's *next* write defers.
    fn write_block_via_cache(
        &self,
        shard: &Shard,
        cache: &Mutex<BlockCache>,
        page_id: u64,
        block: usize,
        data: &[u8],
    ) -> Result<(u32, BlockWrite)> {
        let key = (page_id, block as u32);
        let mut cache = cache.lock().unwrap();
        if let Some(cached) = cache.cached_len(key) {
            if data.len() != cached {
                return Err(Error::Config(format!(
                    "write must supply exactly {cached} B for block {block}, got {}",
                    data.len()
                )));
            }
            let state = shard.state.read().unwrap();
            // quarantine invalidates a page's cached blocks under this
            // cache mutex, so a resident entry implies not-quarantined;
            // the check is belt-and-suspenders for the fence invariant
            if self.integrity.is_some() && state.quarantined.contains(&page_id) {
                return Err(data_loss(page_id));
            }
            cache.absorb_write(key, data);
            shard.metrics.cache_hit();
            let bits = match state.pages.get(&page_id) {
                Some(p) if block < p.frame.n_blocks() => p.frame.block_bits(block),
                _ => 0,
            };
            return Ok((bits, BlockWrite { bits, spilled: false }));
        }
        let r = self.write_block_frame(shard, page_id, block, data)?;
        shard.metrics.cache_miss();
        let evicted = cache.insert(key, data.to_vec(), false, false);
        shard.metrics.cache_admission();
        self.flush_evicted(shard, evicted)?;
        Ok(r)
    }

    /// Write the deferred (dirty) blocks the cache pushed out back
    /// through their frames, under the shard's exclusive lock. Called
    /// with the shard's cache mutex held (lock order: cache, then state).
    fn flush_evicted(&self, shard: &Shard, evicted: Vec<EvictedBlock>) -> Result<()> {
        if evicted.is_empty() {
            return Ok(());
        }
        shard.metrics.cache_evicted(evicted.len() as u64);
        let dirty: Vec<EvictedBlock> = evicted.into_iter().filter(|e| e.dirty).collect();
        if dirty.is_empty() {
            return Ok(());
        }
        let mut state = shard.state.write().unwrap();
        let t0 = Instant::now();
        let r = {
            let PageShard { pages, scratch, crcs, .. } = &mut *state;
            let mut out = Ok(());
            for ev in &dirty {
                // invariant: a cached entry's page is live (remove/put
                // invalidate under the cache mutex we are holding), and
                // never quarantined (quarantine invalidates too)
                let Some(page) = pages.get_mut(&ev.page_id) else {
                    out = Err(Error::Corrupt(format!("page {} not found", ev.page_id)));
                    break;
                };
                let old_term = crc_term(crcs, ev.page_id, &page.frame, ev.block as usize);
                if let Err(e) = page.frame.write_block(ev.block as usize, &ev.data, scratch) {
                    out = Err(e);
                    break;
                }
                fold_crc(crcs, ev.page_id, old_term, &page.frame, ev.block as usize);
                if self.auto_compact && page.frame.patch_len() * 2 > page.frame.compressed_len() {
                    page.frame.compact();
                }
            }
            out
        };
        shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
        if r.is_ok() {
            shard.metrics.deferred_flushed(dirty.len() as u64);
        }
        r
    }

    /// Migrate up to `max_pages` pages of shard `idx` that are encoded
    /// under a version older than `codec.version()`, re-encoding them
    /// under `codec`. The shard lock is dropped between pages, so
    /// foreground GETs/PUTs on this shard interleave with maintenance —
    /// and other shards never see the migration at all. Each page's
    /// decode + re-encode happens under the exclusive guard, so a block
    /// PUT can never be clobbered by a stale re-encode. Returns the
    /// pages migrated.
    pub fn migrate_shard(
        &self,
        idx: usize,
        codec: &Arc<dyn BlockCodec>,
        max_pages: usize,
    ) -> Result<usize> {
        let target = codec.version();
        let shards = self.shards.read().unwrap();
        // a racing resize may have shrunk the topology since the caller
        // snapshotted shard_count(); those pages now live elsewhere
        let Some(shard) = shards.get(idx) else { return Ok(0) };
        let mut lagging: Vec<u64> = {
            let state = shard.state.read().unwrap();
            state
                .pages
                .iter()
                .filter(|(_, p)| p.codec_version() < target)
                .map(|(&id, _)| id)
                .collect()
        };
        lagging.sort_unstable();
        lagging.truncate(max_pages);
        let mut moved = 0;
        for id in lagging {
            let mut cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
            let mut state = shard.state.write().unwrap();
            let t0 = Instant::now();
            {
                let PageShard { pages, scratch, crcs, quarantined } = &mut *state;
                // re-check under the exclusive guard: the page may have
                // been removed or already migrated since the snapshot.
                // Quarantined pages are skipped — re-encoding a corrupt
                // frame would launder the corruption under a fresh
                // digest; they migrate after healing.
                if let Some(page) = pages.get_mut(&id) {
                    if page.codec_version() < target && !quarantined.contains(&id) {
                        // fold deferred cached writes into the frame
                        // first, or the re-encode would resurrect stale
                        // content; clean cached copies stay valid since
                        // the logical content does not change
                        if let Some(cache) = cache.as_deref_mut() {
                            let dirty = cache.dirty_blocks_of_page(id);
                            for b in &dirty {
                                if let Some(data) = cache.data_of((id, *b)) {
                                    page.frame.write_block(*b as usize, data, scratch)?;
                                }
                            }
                            for b in &dirty {
                                cache.mark_clean((id, *b));
                            }
                            if !dirty.is_empty() {
                                shard.metrics.deferred_flushed(dirty.len() as u64);
                            }
                        }
                        let data = page.frame.decompress()?;
                        page.frame = Frame::compress_with(Arc::clone(codec), &data, scratch);
                        // the image changed wholesale: recompute rather
                        // than fold
                        if crcs.contains_key(&id) {
                            crcs.insert(id, page.frame.image_crc());
                        }
                        moved += 1;
                    }
                }
            }
            shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
        }
        Ok(moved)
    }

    // ---- reads -----------------------------------------------------------

    /// Run `f` on a stored page under the shard's read lock (metadata
    /// inspection without copying the page out).
    pub fn with_page<R>(&self, page_id: u64, f: impl FnOnce(&StoredPage) -> R) -> Option<R> {
        let shards = self.shards.read().unwrap();
        let state = shards[Self::route(page_id, shards.len())].state.read().unwrap();
        state.pages.get(&page_id).map(f)
    }

    /// Whether a page is stored.
    pub fn contains(&self, page_id: u64) -> bool {
        let shards = self.shards.read().unwrap();
        let state = shards[Self::route(page_id, shards.len())].state.read().unwrap();
        state.pages.contains_key(&page_id)
    }

    /// Decompress a whole page (each frame carries its own codec, so any
    /// published version decodes). With the cache on, deferred cached
    /// writes are overlaid so the caller always sees the latest content.
    pub fn read(&self, page_id: u64) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        self.read_into(page_id, &mut out)?;
        Ok(out)
    }

    /// Decompress a whole page into `out`, reusing its allocation — the
    /// zero-allocation loop shape for page sweeps
    /// (`tests/alloc_counting.rs` pins it). Deferred cached writes are
    /// overlaid, same as [`Self::read`].
    pub fn read_into(&self, page_id: u64, out: &mut Vec<u8>) -> Result<()> {
        let shards = self.shards.read().unwrap();
        let shard = &shards[Self::route(page_id, shards.len())];
        loop {
            {
                let cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
                let state = shard.state.read().unwrap();
                if self.integrity.is_some() && state.quarantined.contains(&page_id) {
                    return Err(data_loss(page_id));
                }
                let p = match state.pages.get(&page_id) {
                    Some(p) => p,
                    None => return Err(Error::Corrupt(format!("page {page_id} not found"))),
                };
                let clean = match state.crcs.get(&page_id) {
                    Some(&want) if self.verify_reads() => p.frame.image_crc() == want,
                    _ => true,
                };
                if clean {
                    p.frame.decompress_into(out)?;
                    if let Some(cache) = &cache {
                        let bb = p.frame.block_bytes();
                        for b in cache.dirty_blocks_of_page(page_id) {
                            if let Some(data) = cache.data_of((page_id, b)) {
                                let off = b as usize * bb;
                                out[off..off + data.len()].copy_from_slice(data);
                            }
                        }
                    }
                    return Ok(());
                }
            }
            // the shared-lock digest check failed: fence the page — or
            // discover a racing legitimate write refreshed the digest,
            // and retry the read
            self.quarantine_if_bad(shard, page_id)?;
        }
    }

    /// Decode one block of a page into `out[..len]`; returns the bytes
    /// written. O(1) in the page size, allocation-free, and concurrent
    /// with every read on this shard (shared lock side). With the cache
    /// on, a resident block is copied straight out of uncompressed
    /// cache memory — zero decode, zero allocation.
    pub fn read_block(&self, page_id: u64, block: usize, out: &mut [u8]) -> Result<usize> {
        let shards = self.shards.read().unwrap();
        let shard = &shards[Self::route(page_id, shards.len())];
        let t0 = Instant::now();
        let r = match &shard.cache {
            None => self.read_block_frame(shard, page_id, block, out),
            Some(cache) => self.read_block_via_cache(shard, cache, page_id, block, out),
        };
        if r.is_ok() {
            shard.metrics.block_read(t0.elapsed().as_nanos() as u64);
        }
        r
    }

    /// The cacheless block-read path: decode straight from the frame
    /// under the shard's read lock. With `verify_reads` on, the page's
    /// digest is re-verified before the decode — an O(page) hash, the
    /// price of never serving a silently-wrong block (DESIGN.md §13).
    fn read_block_frame(
        &self,
        shard: &Shard,
        page_id: u64,
        block: usize,
        out: &mut [u8],
    ) -> Result<usize> {
        loop {
            {
                let state = shard.state.read().unwrap();
                if self.integrity.is_some() && state.quarantined.contains(&page_id) {
                    return Err(data_loss(page_id));
                }
                match state.pages.get(&page_id) {
                    Some(p) => {
                        let clean = match state.crcs.get(&page_id) {
                            Some(&want) if self.verify_reads() => p.frame.image_crc() == want,
                            _ => true,
                        };
                        if clean {
                            return p.frame.read_block(block, out);
                        }
                    }
                    None => return Err(Error::Corrupt(format!("page {page_id} not found"))),
                }
            }
            self.quarantine_if_bad(shard, page_id)?;
        }
    }

    /// A shared-lock digest check failed: re-verify under the exclusive
    /// lock and fence the page if the mismatch holds. `Err(DataLoss)`
    /// when the page is now (or already was) quarantined; `Ok(())` when
    /// the exclusive re-check came back clean — a legitimate writer
    /// raced the shared-lock check, and the caller retries. Takes the
    /// shard's cache mutex itself, so callers must have dropped theirs.
    fn quarantine_if_bad(&self, shard: &Shard, page_id: u64) -> Result<()> {
        let mut cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
        self.quarantine_if_bad_locked(shard, cache.as_deref_mut(), page_id)
    }

    /// [`Self::quarantine_if_bad`] for callers already holding the
    /// shard's cache mutex (lock order: cache, then state). Dropping
    /// the page's cached blocks here is what upholds the fence
    /// invariant — a resident cache entry always belongs to a
    /// non-quarantined page.
    fn quarantine_if_bad_locked(
        &self,
        shard: &Shard,
        cache: Option<&mut BlockCache>,
        page_id: u64,
    ) -> Result<()> {
        let mut state = shard.state.write().unwrap();
        let t0 = Instant::now();
        let PageShard { pages, crcs, quarantined, .. } = &mut *state;
        let r = if quarantined.contains(&page_id) {
            Err(data_loss(page_id))
        } else {
            let bad = match (pages.get(&page_id), crcs.get(&page_id)) {
                (Some(p), Some(&want)) => p.frame.image_crc() != want,
                _ => false,
            };
            if bad {
                quarantined.insert(page_id);
                if let Some(cache) = cache {
                    cache.invalidate_page(page_id);
                }
                shard.metrics.corrupt_detected();
                shard.metrics.quarantined();
                Err(data_loss(page_id))
            } else {
                Ok(())
            }
        };
        shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
        r
    }

    /// The cached read path: serve hits from cache memory; on a miss,
    /// decode under the shard's read lock and admit the block. Admission
    /// is latency-driven: a miss whose decode cost at least matches the
    /// shard's running mean block-read latency skips probation
    /// (expensive-to-decode blocks are exactly the ones worth keeping
    /// uncompressed), as does any block still remembered by the ghost
    /// history.
    fn read_block_via_cache(
        &self,
        shard: &Shard,
        cache: &Mutex<BlockCache>,
        page_id: u64,
        block: usize,
        out: &mut [u8],
    ) -> Result<usize> {
        let key = (page_id, block as u32);
        let mut cache = cache.lock().unwrap();
        if let Some(data) = cache.get(key) {
            let n = data.len();
            if out.len() < n {
                return Err(Error::Config(format!(
                    "output buffer {} B short of block length {n} B",
                    out.len()
                )));
            }
            out[..n].copy_from_slice(data);
            shard.metrics.cache_hit();
            return Ok(n);
        }
        loop {
            // miss: decode under the state read lock. The cache mutex
            // stays held, so a racing remove/put cannot invalidate the
            // page between this decode and the admission below. With
            // `verify_reads` on, the digest is checked before the
            // decode, so only verified content is ever admitted — a
            // resident cache entry needs no re-verification.
            let d0 = Instant::now();
            let decoded = {
                let state = shard.state.read().unwrap();
                if self.integrity.is_some() && state.quarantined.contains(&page_id) {
                    return Err(data_loss(page_id));
                }
                match state.pages.get(&page_id) {
                    Some(p) => {
                        let clean = match state.crcs.get(&page_id) {
                            Some(&want) if self.verify_reads() => p.frame.image_crc() == want,
                            _ => true,
                        };
                        if clean {
                            Some(p.frame.read_block(block, out)?)
                        } else {
                            None
                        }
                    }
                    None => return Err(Error::Corrupt(format!("page {page_id} not found"))),
                }
            };
            let Some(n) = decoded else {
                self.quarantine_if_bad_locked(shard, Some(&mut cache), page_id)?;
                continue; // exclusive re-check came back clean: retry
            };
            let decode_ns = d0.elapsed().as_nanos() as u64;
            shard.metrics.cache_miss();
            let mean = shard.metrics.block_read_mean_ns();
            let hot = mean > 0.0 && decode_ns as f64 >= mean;
            let evicted = cache.insert(key, out[..n].to_vec(), false, hot);
            shard.metrics.cache_admission();
            self.flush_evicted(shard, evicted)?;
            return Ok(n);
        }
    }

    /// Current exact encoding length of one block of a page, in bits
    /// (the memory simulator's sector accounting reads this). This is
    /// the *compressed tier's* truth: a deferred cached write does not
    /// change it until the block is flushed.
    pub fn block_bits(&self, page_id: u64, block: usize) -> Result<u32> {
        let shards = self.shards.read().unwrap();
        let state = shards[Self::route(page_id, shards.len())].state.read().unwrap();
        if self.integrity.is_some() && state.quarantined.contains(&page_id) {
            return Err(data_loss(page_id));
        }
        match state.pages.get(&page_id) {
            Some(p) if block < p.frame.n_blocks() => Ok(p.frame.block_bits(block)),
            Some(p) => Err(Error::Config(format!(
                "block {block} out of range ({} blocks)",
                p.frame.n_blocks()
            ))),
            None => Err(Error::Corrupt(format!("page {page_id} not found"))),
        }
    }

    // ---- integrity: scrub, quarantine, heal ------------------------------

    /// Re-verify one page's digest — the scrubber's unit of work. The
    /// verification itself runs under the shard's *read* lock (fully
    /// concurrent with foreground reads); only a confirmed mismatch
    /// escalates to the exclusive lock to fence the page.
    pub fn scrub_page(&self, page_id: u64) -> ScrubOutcome {
        if self.integrity.is_none() {
            return ScrubOutcome::Skipped;
        }
        let shards = self.shards.read().unwrap();
        let shard = &shards[Self::route(page_id, shards.len())];
        let bytes = {
            let state = shard.state.read().unwrap();
            if state.quarantined.contains(&page_id) {
                return ScrubOutcome::Skipped;
            }
            match (state.pages.get(&page_id), state.crcs.get(&page_id)) {
                (Some(p), Some(&want)) => {
                    let bytes = p.frame.compressed_len();
                    if p.frame.image_crc() == want {
                        shard.metrics.scrubbed();
                        return ScrubOutcome::Clean { bytes };
                    }
                    bytes
                }
                _ => return ScrubOutcome::Skipped,
            }
        };
        shard.metrics.scrubbed();
        match self.quarantine_if_bad(shard, page_id) {
            Err(_) => ScrubOutcome::Corrupt { bytes },
            // a racing write refreshed the digest between the checks
            Ok(()) => ScrubOutcome::Skipped,
        }
    }

    /// Replace a quarantined page with `page` (recovered from durable
    /// state), lifting the fence. The replacement's digest is computed
    /// fresh before any lock — the store trusts nothing it did not hash
    /// itself. Returns `false` without installing when the page is not
    /// quarantined (already healed, overwritten by a racing `put`, or
    /// removed) — the caller drops its candidate.
    pub fn heal_page(&self, page_id: u64, page: StoredPage) -> bool {
        if self.integrity.is_none() {
            return false;
        }
        let crc = page.frame.image_crc();
        let shards = self.shards.read().unwrap();
        let shard = &shards[Self::route(page_id, shards.len())];
        let mut cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
        let mut state = shard.state.write().unwrap();
        let t0 = Instant::now();
        if !state.quarantined.remove(&page_id) {
            return false;
        }
        if let Some(cache) = cache.as_deref_mut() {
            cache.invalidate_page(page_id);
        }
        state.crcs.insert(page_id, crc);
        state.pages.insert(page_id, page);
        shard.metrics.healed();
        shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
        true
    }

    /// Page ids resident in shard `idx`, sorted — the scrubber's walk
    /// snapshot. An out-of-range index (racing resize) yields an empty
    /// list.
    pub fn shard_page_ids(&self, idx: usize) -> Vec<u64> {
        let shards = self.shards.read().unwrap();
        let Some(shard) = shards.get(idx) else { return Vec::new() };
        let mut ids: Vec<u64> = shard.state.read().unwrap().pages.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Ids currently fenced in quarantine, across all shards, sorted.
    pub fn quarantined_pages(&self) -> Vec<u64> {
        let mut ids = Vec::new();
        let shards = self.shards.read().unwrap();
        for shard in shards.iter() {
            ids.extend(shard.state.read().unwrap().quarantined.iter().copied());
        }
        ids.sort_unstable();
        ids
    }

    /// Service-wide integrity totals: the sum of the per-shard
    /// snapshots.
    pub fn integrity_totals(&self) -> IntegrityTotals {
        IntegrityTotals::from_shards(&self.shard_metrics())
    }

    /// Test/chaos hook: flip one stored bit of `block` of `page_id`
    /// inside the compressed image, bypassing all digest bookkeeping —
    /// exactly what a memory fault does. Deferred cached writes are
    /// flushed first (they were acknowledged; only durable state may
    /// resurrect them) and the page's cached blocks dropped, so the
    /// flipped frame is what the next read actually decodes. Returns
    /// `false` if the page or block does not exist.
    #[doc(hidden)]
    pub fn corrupt_page_block(&self, page_id: u64, block: usize, bit: u64) -> bool {
        self.flush_cache();
        let shards = self.shards.read().unwrap();
        let shard = &shards[Self::route(page_id, shards.len())];
        let mut cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
        if let Some(cache) = cache.as_deref_mut() {
            cache.invalidate_page(page_id);
        }
        let mut state = shard.state.write().unwrap();
        match state.pages.get_mut(&page_id) {
            Some(p) => p.frame.corrupt_block_bit(block, bit),
            None => false,
        }
    }

    // ---- accounting ------------------------------------------------------

    /// Number of stored pages (sums the shards; not an atomic snapshot
    /// under concurrent writers, like any aggregate here).
    pub fn len(&self) -> usize {
        self.shards.read().unwrap().iter().map(|s| s.state.read().unwrap().pages.len()).sum()
    }

    /// Whether the store is empty.
    pub fn is_empty(&self) -> bool {
        self.shards.read().unwrap().iter().all(|s| s.state.read().unwrap().pages.is_empty())
    }

    /// Total physical bytes stored: compressed frames plus any
    /// uncompressed bytes resident in the hot-block cache — the honest
    /// numerator, so compression-ratio reporting cannot flatter itself
    /// by ignoring the cache tier.
    pub fn stored_bytes(&self) -> usize {
        self.shards
            .read()
            .unwrap()
            .iter()
            .map(|s| {
                let cache = s.cache.as_ref().map(|c| c.lock().unwrap());
                let frames = s
                    .state
                    .read()
                    .unwrap()
                    .pages
                    .values()
                    .map(|p| p.stored_len())
                    .sum::<usize>();
                frames + cache.map_or(0, |c| c.resident_bytes())
            })
            .sum()
    }

    /// Total logical bytes stored.
    pub fn logical_bytes(&self) -> usize {
        self.shards
            .read()
            .unwrap()
            .iter()
            .map(|s| {
                s.state.read().unwrap().pages.values().map(|p| p.original_len()).sum::<usize>()
            })
            .sum()
    }

    /// `(logical_bytes, stored_bytes)` in one sweep: each shard's
    /// contribution is read under a single lock acquisition, so the two
    /// numbers are mutually consistent per shard (and the lock traffic
    /// is half of calling the two accessors separately). Stored bytes
    /// include cache-resident uncompressed data, same as
    /// [`Self::stored_bytes`].
    pub fn usage(&self) -> (usize, usize) {
        let mut logical = 0usize;
        let mut stored = 0usize;
        let shards = self.shards.read().unwrap();
        for shard in shards.iter() {
            let cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
            let state = shard.state.read().unwrap();
            for p in state.pages.values() {
                logical += p.original_len();
                stored += p.stored_len();
            }
            stored += cache.map_or(0, |c| c.resident_bytes());
        }
        (logical, stored)
    }

    /// Uncompressed bytes resident in the hot-block cache across all
    /// shards (0 with the cache off).
    pub fn cache_resident_bytes(&self) -> usize {
        self.shards
            .read()
            .unwrap()
            .iter()
            .map(|s| s.cache.as_ref().map_or(0, |c| c.lock().unwrap().resident_bytes()))
            .sum()
    }

    /// Service-wide cache totals: the sum of the per-shard snapshots.
    pub fn cache_totals(&self) -> CacheTotals {
        CacheTotals::from_shards(&self.shard_metrics())
    }

    /// Flush every deferred (dirty) cached block back through its
    /// frame, leaving the cache resident but clean — shutdown, tests,
    /// and accounting sweeps use this to bring the compressed tier up
    /// to date without evicting the hot set. Returns blocks flushed.
    pub fn flush_cache(&self) -> usize {
        let mut flushed = 0usize;
        let shards = self.shards.read().unwrap();
        for shard in shards.iter() {
            let Some(cache) = &shard.cache else { continue };
            let mut cache = cache.lock().unwrap();
            let dirty_pages = cache.dirty_pages();
            if dirty_pages.is_empty() {
                continue;
            }
            let mut state = shard.state.write().unwrap();
            let t0 = Instant::now();
            let PageShard { pages, scratch, crcs, .. } = &mut *state;
            for id in dirty_pages {
                let Some(page) = pages.get_mut(&id) else { continue };
                let dirty = cache.dirty_blocks_of_page(id);
                for b in &dirty {
                    if let Some(data) = cache.data_of((id, *b)) {
                        let old_term = crc_term(crcs, id, &page.frame, *b as usize);
                        // cannot fail for a live cached block; a corrupt
                        // frame surfaces on the next read
                        let _ = page.frame.write_block(*b as usize, data, scratch);
                        fold_crc(crcs, id, old_term, &page.frame, *b as usize);
                    }
                }
                if self.auto_compact && page.frame.patch_len() * 2 > page.frame.compressed_len() {
                    page.frame.compact();
                }
                for b in &dirty {
                    cache.mark_clean((id, *b));
                }
                shard.metrics.deferred_flushed(dirty.len() as u64);
                flushed += dirty.len();
            }
            shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
        }
        flushed
    }

    /// Ids of pages encoded with a version older than `version`, across
    /// all shards, sorted.
    pub fn lagging_pages(&self, version: u64) -> Vec<u64> {
        let mut ids = Vec::new();
        let shards = self.shards.read().unwrap();
        for shard in shards.iter() {
            let state = shard.state.read().unwrap();
            ids.extend(
                state
                    .pages
                    .iter()
                    .filter(|(_, p)| p.codec_version() < version)
                    .map(|(&id, _)| id),
            );
        }
        ids.sort_unstable();
        ids
    }

    /// Per-shard metrics: occupancy gauges read under each shard's read
    /// lock (and cache mutex) plus the wait-free counters. Counter sums
    /// equal the service-wide totals (both sides count each successful
    /// op once). `stored_bytes` includes cache-resident bytes, matching
    /// [`Self::usage`].
    pub fn shard_metrics(&self) -> Vec<ShardMetricsSnapshot> {
        self.shards
            .read()
            .unwrap()
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
                let gauges = cache.as_ref().map_or(CacheGauges::default(), |c| CacheGauges {
                    blocks: c.resident_blocks() as u64,
                    bytes: c.resident_bytes() as u64,
                    dirty_blocks: c.dirty_blocks() as u64,
                    dirty_bytes: c.dirty_bytes() as u64,
                });
                let state = shard.state.read().unwrap();
                let pages = state.pages.len() as u64;
                let logical =
                    state.pages.values().map(|p| p.original_len() as u64).sum::<u64>();
                let stored = state.pages.values().map(|p| p.stored_len() as u64).sum::<u64>()
                    + gauges.bytes;
                shard.metrics.snapshot(i, pages, logical, stored, gauges)
            })
            .collect()
    }

    // ---- elasticity + persistence export ---------------------------------

    /// Resize the store to `new_n` shards **online**: takes the outer
    /// write lock (so it runs exactly when no operation is in flight —
    /// concurrent GETs/PUTs simply queue for the duration), folds every
    /// deferred cached write into its frame, reroutes all pages under
    /// the new topology, and re-splits the cache budget. Per-shard
    /// metrics counters move with surviving shard indices; counters of
    /// retired shards are folded into shard 0, so sums over shards still
    /// equal the service-wide totals. Returns how many pages changed
    /// shard.
    pub fn resize_shards(&self, new_n: usize) -> usize {
        let new_n = new_n.max(1);
        let mut shards = self.shards.write().unwrap();
        let old_n = shards.len();
        if old_n == new_n {
            return 0;
        }
        // exclusive access: get_mut everywhere, no inner locking
        let mut all: Vec<(u64, StoredPage)> = Vec::new();
        let mut all_crcs: HashMap<u64, u32> = HashMap::new();
        let mut all_quarantined: HashSet<u64> = HashSet::new();
        for shard in shards.iter_mut() {
            let Shard { state, metrics, cache } = shard;
            let state = state.get_mut().unwrap();
            if let Some(cache) = cache {
                let cache = cache.get_mut().unwrap();
                let PageShard { pages, scratch, crcs, .. } = state;
                for id in cache.dirty_pages() {
                    let Some(page) = pages.get_mut(&id) else { continue };
                    let dirty = cache.dirty_blocks_of_page(id);
                    for b in &dirty {
                        if let Some(data) = cache.data_of((id, *b)) {
                            let old_term = crc_term(crcs, id, &page.frame, *b as usize);
                            // cached blocks index valid blocks of a live
                            // frame; a corrupt frame surfaces on read
                            let _ = page.frame.write_block(*b as usize, data, scratch);
                            fold_crc(crcs, id, old_term, &page.frame, *b as usize);
                        }
                    }
                    if self.auto_compact
                        && page.frame.patch_len() * 2 > page.frame.compressed_len()
                    {
                        page.frame.compact();
                    }
                    metrics.deferred_flushed(dirty.len() as u64);
                }
            }
            all_crcs.extend(state.crcs.drain());
            all_quarantined.extend(state.quarantined.drain());
            all.extend(state.pages.drain());
        }
        let moved = all
            .iter()
            .filter(|(id, _)| Self::route(*id, old_n) != Self::route(*id, new_n))
            .count();
        let mut old_metrics: Vec<ShardMetrics> =
            std::mem::take(&mut *shards).into_iter().map(|s| s.metrics).collect();
        let mut rebuilt: Vec<Shard> = (0..new_n)
            .map(|i| Shard {
                state: RwLock::new(PageShard::default()),
                metrics: if i < old_metrics.len() {
                    std::mem::replace(&mut old_metrics[i], ShardMetrics::new())
                } else {
                    ShardMetrics::new()
                },
                cache: if self.cache_bytes > 0 {
                    Some(Mutex::new(BlockCache::new((self.cache_bytes / new_n).max(256))))
                } else {
                    None
                },
            })
            .collect();
        for retired in old_metrics.into_iter().skip(new_n) {
            rebuilt[0].metrics.absorb(&retired);
        }
        for (id, page) in all {
            let idx = Self::route(id, new_n);
            let st = rebuilt[idx].state.get_mut().unwrap();
            if let Some(crc) = all_crcs.remove(&id) {
                st.crcs.insert(id, crc);
            }
            if all_quarantined.remove(&id) {
                st.quarantined.insert(id);
            }
            st.pages.insert(id, page);
        }
        *shards = rebuilt;
        moved
    }

    /// Every published codec version, sorted by version — the checkpoint
    /// writer snapshots these into the manifest.
    pub fn codecs(&self) -> Vec<Arc<dyn BlockCodec>> {
        let mut v: Vec<Arc<dyn BlockCodec>> =
            self.codecs.read().unwrap().values().cloned().collect();
        v.sort_by_key(|c| c.version());
        v
    }

    /// Serialize one shard's pages as `(page_id, GBC1 container bytes)`,
    /// sorted by page id for deterministic segment files. The caller
    /// (the checkpoint writer) flushes the block cache first so frames
    /// hold the complete logical state. An out-of-range index (racing
    /// resize) yields an empty export.
    pub fn export_shard(&self, idx: usize) -> Vec<(u64, Vec<u8>)> {
        let shards = self.shards.read().unwrap();
        let Some(shard) = shards.get(idx) else { return Vec::new() };
        let state = shard.state.read().unwrap();
        let mut out: Vec<(u64, Vec<u8>)> = state
            .pages
            .iter()
            .map(|(&id, p)| (id, p.frame.to_container().to_bytes()))
            .collect();
        out.sort_unstable_by_key(|(id, _)| *id);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdi::{analyze, table::GlobalBaseTable, GbdiCodec, GbdiConfig};
    use crate::value::WordSize;
    use crate::workloads;

    fn compress_page(data: &[u8], codec: &Arc<dyn BlockCodec>) -> StoredPage {
        StoredPage { frame: Frame::compress(Arc::clone(codec), data) }
    }

    #[test]
    fn pages_survive_codec_swaps() {
        let cfg = GbdiConfig::default();
        let img_a = workloads::by_name("mcf").unwrap().generate(4096, 1);
        let img_b = workloads::by_name("svm").unwrap().generate(4096, 1);
        let mut t1 = analyze::analyze_image(&img_a, &cfg);
        t1.version = 1;
        let mut t2 = analyze::analyze_image(&img_b, &cfg);
        t2.version = 2;
        let c1: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t1, cfg.clone()));
        let c2: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t2, cfg.clone()));

        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&c1));
        store.put(10, compress_page(&img_a, &c1));
        store.publish_codec(Arc::clone(&c2));
        store.put(20, compress_page(&img_b, &c2));

        // both decode bit-exactly despite different codec versions
        assert_eq!(store.read(10).unwrap(), img_a);
        assert_eq!(store.read(20).unwrap(), img_b);
        assert_eq!(store.lagging_pages(2), vec![10]);
        assert_eq!(store.lagging_pages(1), Vec::<u64>::new());
    }

    #[test]
    fn block_reads_and_writes_hit_frames_not_pages() {
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("mcf").unwrap().generate(4096, 9);
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        // single-block GET matches the image slice
        let mut buf = [0u8; 64];
        for i in [0usize, 7, 63] {
            let n = store.read_block(1, i, &mut buf).unwrap();
            assert_eq!(&buf[..n], &img[i * 64..(i + 1) * 64]);
        }
        // single-block PUT is visible to both block and page reads
        let line = [0x5Au8; 64];
        store.write_block(1, 5, &line).unwrap();
        let n = store.read_block(1, 5, &mut buf).unwrap();
        assert_eq!(&buf[..n], &line[..]);
        let mut expect = img.clone();
        expect[5 * 64..6 * 64].copy_from_slice(&line);
        assert_eq!(store.read(1).unwrap(), expect);
        // out-of-range accesses error
        assert!(store.read_block(1, 64, &mut buf).is_err());
        assert!(store.read_block(99, 0, &mut buf).is_err());
        assert!(store.write_block(99, 0, &line).is_err());
    }

    #[test]
    fn sustained_block_writes_keep_storage_bounded() {
        // growth-spill garbage must not accumulate without bound: the
        // store compacts a frame once patch bytes dominate its footprint
        let cfg = GbdiConfig::default();
        let img = vec![0u8; 4096];
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        let mut rng = crate::util::prng::Rng::new(5);
        let mut noisy = [0u8; 64];
        let mut expect = img.clone();
        for round in 0..200 {
            let blk = (round * 7) % 64;
            if round % 3 == 2 {
                noisy[..].fill(0);
            } else {
                rng.fill_bytes(&mut noisy);
            }
            store.write_block(1, blk, &noisy).unwrap();
            expect[blk * 64..(blk + 1) * 64].copy_from_slice(&noisy);
        }
        // bound: the page never stores more than ~2x its worst-case raw
        // footprint (64 raw blocks + framing), however many spills happened
        let stored = store.get(1).unwrap().stored_len();
        assert!(stored < 2 * (4096 + 4096 / 64 * 3 + 16), "stored {stored} B unbounded");
        assert_eq!(store.read(1).unwrap(), expect, "content survives compactions");
    }

    #[test]
    fn heterogeneous_codecs_coexist() {
        // the ring is codec-agnostic: a BDI page (version 0) and a GBDI
        // page (version 3) live side by side
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("fluidanimate").unwrap().generate(4096, 2);
        let bdi: Arc<dyn BlockCodec> =
            Arc::new(crate::baselines::bdi::Bdi { block_bytes: cfg.block_bytes });
        let mut t = analyze::analyze_image(&img, &cfg);
        t.version = 3;
        let gbdi: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t, cfg));

        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&bdi));
        store.put(1, compress_page(&img, &bdi));
        store.publish_codec(Arc::clone(&gbdi));
        store.put(2, compress_page(&img, &gbdi));
        assert_eq!(store.read(1).unwrap(), img);
        assert_eq!(store.read(2).unwrap(), img);
        assert_eq!(store.codec_count(), 2);
    }

    #[test]
    fn missing_page_and_codec_error() {
        let store = PageStore::new();
        assert!(store.read(99).is_err());
    }

    #[test]
    fn gc_keeps_referenced_versions() {
        let cfg = GbdiConfig::default();
        let img = vec![7u8; 4096];
        let mut store = PageStore::new();
        for v in 1..=5 {
            let t = GlobalBaseTable::new(vec![(v * 1000, 8)], WordSize::W32, v);
            let codec: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t, cfg.clone()));
            store.publish_codec(Arc::clone(&codec));
            if v == 2 {
                store.put(1, compress_page(&img, &codec));
            }
        }
        let dropped = store.gc_codecs(1);
        // v1, v3, v4 droppable; v2 referenced; v5 newest kept
        assert_eq!(dropped, 3);
        assert!(store.codec(2).is_some());
        assert!(store.codec(5).is_some());
        assert_eq!(store.read(1).unwrap(), img);
    }

    #[test]
    fn accounting() {
        let cfg = GbdiConfig::default();
        let img = vec![0u8; 8192];
        let t = analyze::analyze_image(&img, &cfg);
        let codec: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t, cfg));
        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        assert_eq!(store.len(), 1);
        assert_eq!(store.logical_bytes(), 8192);
        assert!(store.stored_bytes() < 2048, "zeros compress: {}", store.stored_bytes());
        store.remove(1).unwrap();
        assert!(store.is_empty());
    }

    #[test]
    fn sharded_routing_covers_all_shards_and_is_stable() {
        let store = ShardedPageStore::new(7);
        assert_eq!(store.shard_count(), 7);
        let mut seen = [false; 7];
        for id in 0..512u64 {
            let s = store.shard_of(id);
            assert!(s < 7);
            assert_eq!(s, store.shard_of(id), "routing must be deterministic");
            seen[s] = true;
        }
        assert!(seen.iter().all(|&s| s), "dense ids must spread over every shard");
        // a single shard degenerates to "everything routes to 0"
        let one = ShardedPageStore::new(1);
        assert!((0..100).all(|id| one.shard_of(id) == 0));
        // shard count is clamped to at least one
        assert_eq!(ShardedPageStore::new(0).shard_count(), 1);
    }

    #[test]
    fn sharded_store_serves_pages_and_blocks() {
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("mcf").unwrap().generate(4096, 9);
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(3);
        store.publish_codec(Arc::clone(&codec));
        for id in 0..12u64 {
            store.put(id, compress_page(&img, &codec));
        }
        assert_eq!(store.len(), 12);
        assert!(store.contains(5) && !store.contains(99));
        assert_eq!(store.logical_bytes(), 12 * 4096);
        assert_eq!(store.usage(), (store.logical_bytes(), store.stored_bytes()));
        let mut buf = [0u8; 64];
        for id in [0u64, 5, 11] {
            assert_eq!(store.read(id).unwrap(), img);
            let n = store.read_block(id, 7, &mut buf).unwrap();
            assert_eq!(&buf[..n], &img[7 * 64..8 * 64]);
        }
        // block write lands and block_bits tracks it
        let line = [0x5Au8; 64];
        let wr = store.write_block(3, 5, &line).unwrap();
        assert_eq!(store.block_bits(3, 5).unwrap(), wr.bits);
        let n = store.read_block(3, 5, &mut buf).unwrap();
        assert_eq!(&buf[..n], &line[..]);
        // errors on the right surface
        assert!(store.read(99).is_err());
        assert!(store.read_block(0, 64, &mut buf).is_err());
        assert!(store.write_block(99, 0, &line).is_err());
        assert!(store.block_bits(0, 64).is_err());
        assert!(store.block_bits(99, 0).is_err());
        // metadata inspection without copying
        assert_eq!(store.with_page(0, |p| p.original_len()), Some(4096));
        assert_eq!(store.with_page(99, |p| p.original_len()), None);
        // removal
        assert!(store.remove(0).is_some());
        assert!(store.remove(0).is_none());
        assert_eq!(store.len(), 11);
    }

    #[test]
    fn sharded_put_batch_takes_each_shard_once() {
        let cfg = GbdiConfig::default();
        let img = vec![3u8; 4096];
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(4);
        store.publish_codec(Arc::clone(&codec));
        let batch: Vec<(u64, StoredPage)> =
            (0..64u64).map(|id| (id, compress_page(&img, &codec))).collect();
        store.put_batch(batch);
        assert_eq!(store.len(), 64);
        for id in 0..64u64 {
            assert_eq!(store.read(id).unwrap(), img);
        }
        // each non-empty shard was locked exactly once for the batch
        let snaps = store.shard_metrics();
        assert_eq!(snaps.len(), 4);
        let total_pages: u64 = snaps.iter().map(|s| s.pages).sum();
        assert_eq!(total_pages, 64);
        for s in &snaps {
            if s.pages > 0 {
                assert_eq!(s.lock_holds, 1, "shard {} locked once per batch", s.shard);
            }
        }
        // empty batches are a no-op
        store.put_batch(Vec::new());
        assert_eq!(store.len(), 64);
    }

    #[test]
    fn sharded_migration_walks_one_shard_at_a_time() {
        let cfg = GbdiConfig::default();
        let img_a = workloads::by_name("mcf").unwrap().generate(4096, 1);
        let img_b = workloads::by_name("svm").unwrap().generate(4096, 2);
        let mut t1 = analyze::analyze_image(&img_a, &cfg);
        t1.version = 1;
        let mut t2 = analyze::analyze_image(&img_b, &cfg);
        t2.version = 2;
        let c1: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t1, cfg.clone()));
        let c2: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t2, cfg));
        let store = ShardedPageStore::new(2);
        store.publish_codec(Arc::clone(&c1));
        for id in 0..16u64 {
            store.put(id, compress_page(&img_a, &c1));
        }
        store.publish_codec(Arc::clone(&c2));
        assert_eq!(store.lagging_pages(2).len(), 16);
        // migrate shard by shard under a per-call budget
        let mut moved = 0;
        for shard in 0..store.shard_count() {
            loop {
                let n = store.migrate_shard(shard, &c2, 3).unwrap();
                moved += n;
                if n == 0 {
                    break;
                }
            }
        }
        assert_eq!(moved, 16);
        assert!(store.lagging_pages(2).is_empty());
        for id in 0..16u64 {
            assert_eq!(store.read(id).unwrap(), img_a, "page {id} after migration");
            assert_eq!(store.with_page(id, |p| p.codec_version()), Some(2));
        }
        // a second walk is a no-op
        assert_eq!(store.migrate_shard(0, &c2, 100).unwrap(), 0);
    }

    #[test]
    fn sharded_gc_keeps_referenced_versions() {
        let cfg = GbdiConfig::default();
        let img = vec![7u8; 4096];
        let store = ShardedPageStore::new(3);
        for v in 1..=5 {
            let t = GlobalBaseTable::new(vec![(v * 1000, 8)], WordSize::W32, v);
            let codec: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t, cfg.clone()));
            store.publish_codec(Arc::clone(&codec));
            if v == 2 {
                store.put(1, compress_page(&img, &codec));
            }
        }
        assert_eq!(store.codec_count(), 5);
        let dropped = store.gc_codecs(1);
        // v1, v3, v4 droppable; v2 referenced; v5 newest kept
        assert_eq!(dropped, 3);
        assert!(store.codec(2).is_some());
        assert!(store.codec(5).is_some());
        assert!(store.codec(1).is_none());
        assert_eq!(store.read(1).unwrap(), img);
    }

    #[test]
    fn sharded_sustained_writes_keep_storage_bounded() {
        // same compaction policy as the single-lock store: patch-region
        // garbage must not accumulate without bound
        let cfg = GbdiConfig::default();
        let img = vec![0u8; 4096];
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(2);
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        let mut rng = crate::util::prng::Rng::new(5);
        let mut noisy = [0u8; 64];
        let mut expect = img.clone();
        for round in 0..200 {
            let blk = (round * 7) % 64;
            if round % 3 == 2 {
                noisy[..].fill(0);
            } else {
                rng.fill_bytes(&mut noisy);
            }
            store.write_block(1, blk, &noisy).unwrap();
            expect[blk * 64..(blk + 1) * 64].copy_from_slice(&noisy);
        }
        let stored = store.with_page(1, |p| p.stored_len()).unwrap();
        assert!(stored < 2 * (4096 + 4096 / 64 * 3 + 16), "stored {stored} B unbounded");
        assert_eq!(store.read(1).unwrap(), expect, "content survives compactions");
        // write latencies and lock holds were recorded on page 1's shard
        let snaps = store.shard_metrics();
        let shard = &snaps[store.shard_of(1)];
        assert_eq!(shard.block_writes, 200);
        assert!(shard.block_write_mean_ns() > 0.0);
        assert!(shard.lock_holds >= 200);
        assert!(shard.lock_hold_mean_ns() > 0.0);
    }

    #[test]
    fn cached_store_serves_hits_and_defers_writes() {
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("mcf").unwrap().generate(4096, 9);
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(2).with_cache(1 << 20);
        assert!(store.cache_enabled());
        assert!(!ShardedPageStore::new(2).cache_enabled());
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        let mut buf = [0u8; 64];
        // first read misses and admits, second hits straight from cache
        store.read_block(1, 3, &mut buf).unwrap();
        assert_eq!(&buf[..], &img[3 * 64..4 * 64]);
        store.read_block(1, 3, &mut buf).unwrap();
        assert_eq!(&buf[..], &img[3 * 64..4 * 64]);
        let t = store.cache_totals();
        assert_eq!((t.hits, t.misses, t.admissions), (1, 1, 1));
        // a write to the resident block is absorbed: framing unchanged
        let bits_before = store.block_bits(1, 3).unwrap();
        let line = [0x5Au8; 64];
        let wr = store.write_block(1, 3, &line).unwrap();
        assert_eq!(wr.bits, bits_before);
        assert!(!wr.spilled);
        assert_eq!(store.block_bits(1, 3).unwrap(), bits_before, "recompression deferred");
        // reads see the deferred write, block- and page-granular
        let n = store.read_block(1, 3, &mut buf).unwrap();
        assert_eq!(&buf[..n], &line[..]);
        let mut expect = img.clone();
        expect[3 * 64..4 * 64].copy_from_slice(&line);
        assert_eq!(store.read(1).unwrap(), expect);
        assert_eq!(store.cache_totals().dirty_blocks, 1);
        // flushing brings the compressed tier up to date
        assert_eq!(store.flush_cache(), 1);
        assert_eq!(store.cache_totals().dirty_blocks, 0);
        assert_eq!(store.read(1).unwrap(), expect);
        assert_eq!(store.cache_totals().deferred_flushes, 1);
        // wrong-length writes error without corrupting the cache
        assert!(store.write_block(1, 3, &[0u8; 32]).is_err());
        let n = store.read_block(1, 3, &mut buf).unwrap();
        assert_eq!(&buf[..n], &line[..]);
        // a cold write goes through the frame, then admits the block
        store.write_block(1, 60, &line).unwrap();
        let n = store.read_block(1, 60, &mut buf).unwrap();
        assert_eq!(&buf[..n], &line[..]);
        // error surface matches the cacheless store
        assert!(store.read_block(1, 64, &mut buf).is_err());
        assert!(store.read_block(99, 0, &mut buf).is_err());
        assert!(store.write_block(99, 0, &line).is_err());
    }

    #[test]
    fn cached_accounting_and_remove_fold_deferred_writes() {
        let cfg = GbdiConfig::default();
        let img = vec![0u8; 4096];
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(1).with_cache(64 * 1024);
        store.publish_codec(Arc::clone(&codec));
        store.put(5, compress_page(&img, &codec));
        let mut buf = [0u8; 64];
        store.read_block(5, 0, &mut buf).unwrap(); // admit
        let line = [7u8; 64];
        store.write_block(5, 0, &line).unwrap(); // absorbed, now dirty
        // stored accounting charges the cache-resident bytes
        let (logical, stored) = store.usage();
        assert_eq!(logical, 4096);
        let frames = store.with_page(5, |p| p.stored_len()).unwrap();
        assert_eq!(stored, frames + 64);
        assert_eq!(store.stored_bytes(), stored);
        assert_eq!(store.cache_resident_bytes(), 64);
        let snaps = store.shard_metrics();
        assert_eq!(snaps[0].cached_blocks, 1);
        assert_eq!(snaps[0].cached_bytes, 64);
        assert_eq!(snaps[0].cached_dirty_blocks, 1);
        assert_eq!(snaps[0].cached_dirty_bytes, 64);
        assert_eq!(snaps[0].stored_bytes, stored as u64);
        // remove hands back the page with the deferred write folded in
        let page = store.remove(5).unwrap();
        assert_eq!(&page.frame.decompress().unwrap()[..64], &line[..]);
        assert_eq!(store.cache_resident_bytes(), 0);
        assert_eq!(store.cache_totals().deferred_flushes, 1);
        assert!(store.is_empty());
    }

    #[test]
    fn put_overwrite_invalidates_cached_blocks() {
        let cfg = GbdiConfig::default();
        let img_a = workloads::by_name("mcf").unwrap().generate(4096, 1);
        let img_b = workloads::by_name("svm").unwrap().generate(4096, 2);
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img_a, &cfg), cfg));
        let store = ShardedPageStore::new(2).with_cache(1 << 20);
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img_a, &codec));
        let mut buf = [0u8; 64];
        store.read_block(1, 0, &mut buf).unwrap();
        // write a deferred update, then overwrite the whole page: the
        // fresh image supersedes the cached (and dirty) blocks
        store.write_block(1, 0, &[9u8; 64]).unwrap();
        store.put(1, compress_page(&img_b, &codec));
        let n = store.read_block(1, 0, &mut buf).unwrap();
        assert_eq!(&buf[..n], &img_b[..64]);
        assert_eq!(store.read(1).unwrap(), img_b);
    }

    #[test]
    fn cached_store_stays_bounded_and_flushes_evictions() {
        // a cache far smaller than the write working set: every
        // deferred write must come back via an eviction flush, and the
        // final content must match a cacheless run
        let cfg = GbdiConfig::default();
        let img = vec![0u8; 4096];
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(1).with_cache(512); // 8 blocks
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        let mut rng = crate::util::prng::Rng::new(5);
        let mut noisy = [0u8; 64];
        let mut expect = img.clone();
        for round in 0..200 {
            let blk = (round * 7) % 64;
            if round % 3 == 2 {
                noisy[..].fill(0);
            } else {
                rng.fill_bytes(&mut noisy);
            }
            store.write_block(1, blk, &noisy).unwrap();
            expect[blk * 64..(blk + 1) * 64].copy_from_slice(&noisy);
        }
        assert_eq!(store.read(1).unwrap(), expect);
        let t = store.cache_totals();
        assert!(t.cached_bytes <= 512, "cache over budget: {} B", t.cached_bytes);
        assert!(t.evictions > 0, "a 8-block cache must evict under 200 writes");
        store.flush_cache();
        assert_eq!(store.read(1).unwrap(), expect, "content survives full flush");
        let stored = store.with_page(1, |p| p.stored_len()).unwrap();
        assert!(stored < 2 * (4096 + 4096 / 64 * 3 + 16), "stored {stored} B unbounded");
    }

    fn integrity_store(shards: usize, verify: bool, cache: usize) -> ShardedPageStore {
        let mut s = ShardedPageStore::new(shards);
        if cache > 0 {
            s = s.with_cache(cache);
        }
        s.with_integrity(IntegrityConfig { enabled: true, verify_reads: verify, scrub_mib_s: 8 })
    }

    #[test]
    fn integrity_digests_survive_every_mutation_path() {
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("mcf").unwrap().generate(4096, 3);
        let mut t1 = analyze::analyze_image(&img, &cfg);
        t1.version = 1;
        let mut t2 = analyze::analyze_image(&img, &cfg);
        t2.version = 2;
        let c1: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t1, cfg.clone()));
        let c2: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t2, cfg));
        let store = integrity_store(3, false, 2048);
        assert!(store.integrity_enabled());
        store.publish_codec(Arc::clone(&c1));
        store.publish_codec(Arc::clone(&c2));
        for id in 0..10u64 {
            store.put(id, compress_page(&img, &c1));
        }
        let mut ids: Vec<u64> =
            (0..store.shard_count()).flat_map(|s| store.shard_page_ids(s)).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10u64).collect::<Vec<_>>());
        let scrub_all = |store: &ShardedPageStore, what: &str| {
            for id in 0..10u64 {
                match store.scrub_page(id) {
                    ScrubOutcome::Clean { .. } => {}
                    o => panic!("page {id} after {what}: {o:?}"),
                }
            }
        };
        scrub_all(&store, "put");
        // block writes across absorb / spill / evict-flush / compact —
        // the incremental digest must track all of them
        let mut rng = crate::util::prng::Rng::new(11);
        let mut noisy = [0u8; 64];
        for round in 0..120usize {
            let id = round as u64 % 10;
            let blk = (round * 13) % 64;
            rng.fill_bytes(&mut noisy);
            store.write_block(id, blk, &noisy).unwrap();
        }
        store.flush_cache();
        scrub_all(&store, "writes+flush");
        store.resize_shards(5);
        scrub_all(&store, "resize");
        for shard in 0..store.shard_count() {
            while store.migrate_shard(shard, &c2, 4).unwrap() > 0 {}
        }
        scrub_all(&store, "migration");
        assert_eq!(store.integrity_totals().corrupt_detected, 0);
    }

    #[test]
    fn corruption_quarantines_heals_and_counts() {
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("svm").unwrap().generate(4096, 7);
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = integrity_store(2, false, 0);
        store.publish_codec(Arc::clone(&codec));
        for id in 0..4u64 {
            store.put(id, compress_page(&img, &codec));
        }
        let blk = (0..64usize).find(|&b| store.block_bits(2, b).unwrap() > 0).unwrap();
        assert!(store.corrupt_page_block(2, blk, 17));
        // verify_reads is off: detection falls to the scrubber
        match store.scrub_page(2) {
            ScrubOutcome::Corrupt { bytes } => assert!(bytes > 0),
            o => panic!("expected Corrupt, got {o:?}"),
        }
        assert_eq!(store.quarantined_pages(), vec![2]);
        // every surface answers DataLoss, never possibly-wrong data
        let mut buf = [0u8; 64];
        assert!(matches!(store.read(2), Err(Error::DataLoss(_))));
        assert!(matches!(store.read_block(2, 0, &mut buf), Err(Error::DataLoss(_))));
        assert!(matches!(store.write_block(2, 0, &[0u8; 64]), Err(Error::DataLoss(_))));
        assert!(matches!(store.block_bits(2, 0), Err(Error::DataLoss(_))));
        // re-scrubbing a quarantined page is a no-op
        assert_eq!(store.scrub_page(2), ScrubOutcome::Skipped);
        // other pages are unaffected
        assert_eq!(store.read(1).unwrap(), img);
        let t = store.integrity_totals();
        assert_eq!((t.corrupt_detected, t.quarantined, t.healed), (1, 1, 0));
        // heal from a pristine copy: the fence lifts, the content is back
        assert!(store.heal_page(2, compress_page(&img, &codec)));
        assert!(!store.heal_page(2, compress_page(&img, &codec)), "double heal is a no-op");
        assert_eq!(store.read(2).unwrap(), img);
        let stored = store.with_page(2, |p| p.stored_len()).unwrap();
        assert_eq!(store.scrub_page(2), ScrubOutcome::Clean { bytes: stored });
        assert_eq!(store.integrity_totals().healed, 1);
        assert!(store.quarantined_pages().is_empty());
        // a full-page overwrite also lifts the fence: fresh content
        // supersedes whatever was lost
        assert!(store.corrupt_page_block(3, blk, 2));
        assert!(matches!(store.scrub_page(3), ScrubOutcome::Corrupt { .. }));
        store.put(3, compress_page(&img, &codec));
        assert_eq!(store.read(3).unwrap(), img);
    }

    #[test]
    fn verified_reads_fence_corruption_immediately() {
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("mcf").unwrap().generate(4096, 5);
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        for cache in [0usize, 1 << 20] {
            let store = integrity_store(2, true, cache);
            store.publish_codec(Arc::clone(&codec));
            store.put(1, compress_page(&img, &codec));
            assert_eq!(store.read(1).unwrap(), img, "verified read passes clean");
            let blk = (0..64usize).find(|&b| store.block_bits(1, b).unwrap() > 0).unwrap();
            let mut buf = [0u8; 64];
            store.read_block(1, blk, &mut buf).unwrap();
            assert!(store.corrupt_page_block(1, blk, 3));
            // the very next decode sees the flip: DataLoss, never garbage
            assert!(
                matches!(store.read_block(1, blk, &mut buf), Err(Error::DataLoss(_))),
                "cache {cache}"
            );
            assert!(matches!(store.read(1), Err(Error::DataLoss(_))));
            let t = store.integrity_totals();
            assert_eq!((t.corrupt_detected, t.quarantined), (1, 1), "cache {cache}");
        }
    }

    #[test]
    fn integrity_off_stores_no_digests_and_never_fences() {
        let cfg = GbdiConfig::default();
        let img = vec![9u8; 4096];
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(2)
            .with_integrity(IntegrityConfig { enabled: false, ..IntegrityConfig::default() });
        assert!(!store.integrity_enabled());
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        assert_eq!(store.scrub_page(1), ScrubOutcome::Skipped);
        assert!(store.corrupt_page_block(1, 0, 1));
        // off = trust the bits, exactly the pre-integrity behavior: the
        // read is served (or fails as Corrupt), never fenced
        assert!(!matches!(store.read(1), Err(Error::DataLoss(_))));
        assert!(store.quarantined_pages().is_empty());
        assert!(!store.heal_page(1, compress_page(&img, &codec)));
    }

    #[test]
    fn with_integrity_backfills_resident_pages() {
        // a store populated *before* the plane turns on — the recovery
        // path: recovered pages must start covered, not trusted blindly
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("fluidanimate").unwrap().generate(4096, 1);
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(3);
        store.publish_codec(Arc::clone(&codec));
        for id in 0..6u64 {
            store.put(id, compress_page(&img, &codec));
        }
        let store = store.with_integrity(IntegrityConfig {
            enabled: true,
            verify_reads: true,
            scrub_mib_s: 0,
        });
        for id in 0..6u64 {
            assert!(matches!(store.scrub_page(id), ScrubOutcome::Clean { .. }));
            assert_eq!(store.read(id).unwrap(), img);
        }
    }
}
