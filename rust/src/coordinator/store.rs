//! Versioned compressed-page store: pages encoded under different codec
//! versions coexist; the codec ring keeps every published version so any
//! page stays decodable until migrated. Codec-agnostic: the ring holds
//! `Arc<dyn BlockCodec>` — GBDI tables are just one kind of versioned
//! codec state.
//!
//! Pages are stored as random-access [`Frame`]s, so the serving paths
//! are block-granular: [`PageStore::read_block`] decodes one cache line
//! out of a compressed page in O(1) without materializing the page, and
//! [`PageStore::write_block`] recompresses one line in place (spilling
//! to the frame's patch region when it grows) instead of round-tripping
//! the whole page.
//!
//! Two stores live here (DESIGN.md §8):
//!
//! * [`PageStore`] — the plain single-owner store: no interior locking,
//!   `&mut self` writes. It is the *reference semantics* — the sharded
//!   store must be observationally identical to it under any
//!   single-threaded interleaving of operations
//!   (`tests/sharded_store.rs` enforces this for N ∈ {1, 2, 7}).
//! * [`ShardedPageStore`] — N independently locked shards routed by a
//!   page-id hash, each with its own [`Scratch`] and
//!   [`ShardMetrics`](super::metrics::ShardMetrics), sharing **one**
//!   codec ring behind its own lock so publishing a new table version
//!   is a single O(1) insert, not an O(shards) fan-out. All methods are
//!   `&self`: callers on different shards never contend.
//!
//! The sharded store can additionally carry a **hot-block cache tier**
//! ([`Self::with_cache`](ShardedPageStore::with_cache)): one bounded
//! S3-FIFO [`BlockCache`](super::cache::BlockCache) per shard, serving
//! block-read hits straight from uncompressed memory and absorbing
//! block writes to resident blocks as *deferred recompressions* — the
//! dirty block stays uncompressed until it cools out of the cache (or
//! its page is removed/migrated), and only then goes back through the
//! normal [`Frame::write_block`] path. Lock order is fixed: a shard's
//! cache mutex is always acquired *before* its state lock, so eviction
//! flushes can take the state lock without deadlocking. With the cache
//! off (the default), every code path is byte-identical to before.

use super::cache::{BlockCache, EvictedBlock};
use super::metrics::{CacheGauges, CacheTotals, ShardMetrics, ShardMetricsSnapshot};
use crate::codec::{BlockCodec, Scratch};
use crate::frame::{BlockWrite, Frame};
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// One stored page: a compressed random-access frame. The codec version
/// it references is the frame's codec's version.
pub struct StoredPage {
    /// The page's compressed form + block index.
    pub frame: Frame,
}

impl StoredPage {
    /// Codec version the payload references (GBDI: table version).
    pub fn codec_version(&self) -> u64 {
        self.frame.codec().version()
    }

    /// Original (logical) length in bytes.
    pub fn original_len(&self) -> usize {
        self.frame.len()
    }

    /// Compressed bytes including framing (payload + patches + index).
    pub fn stored_len(&self) -> usize {
        self.frame.compressed_len()
    }
}

/// The page store + codec ring.
#[derive(Default)]
pub struct PageStore {
    pages: HashMap<u64, StoredPage>,
    codecs: HashMap<u64, Arc<dyn BlockCodec>>,
    /// Reusable buffers for the block-granular write path.
    scratch: Scratch,
}

impl PageStore {
    /// Empty store.
    pub fn new() -> Self {
        PageStore::default()
    }

    /// Publish a codec version (idempotent; versions are immutable).
    pub fn publish_codec(&mut self, codec: Arc<dyn BlockCodec>) {
        self.codecs.entry(codec.version()).or_insert(codec);
    }

    /// Look up a published codec version.
    pub fn codec(&self, version: u64) -> Option<&Arc<dyn BlockCodec>> {
        self.codecs.get(&version)
    }

    /// Number of published codec versions.
    pub fn codec_count(&self) -> usize {
        self.codecs.len()
    }

    /// Insert/overwrite a page.
    pub fn put(&mut self, page_id: u64, page: StoredPage) {
        debug_assert!(
            self.codecs.contains_key(&page.codec_version()),
            "page references unpublished codec v{}",
            page.codec_version()
        );
        self.pages.insert(page_id, page);
    }

    /// Get a stored page.
    pub fn get(&self, page_id: u64) -> Option<&StoredPage> {
        self.pages.get(&page_id)
    }

    /// Remove a page (returns it).
    pub fn remove(&mut self, page_id: u64) -> Option<StoredPage> {
        self.pages.remove(&page_id)
    }

    /// Number of stored pages.
    pub fn len(&self) -> usize {
        self.pages.len()
    }

    /// Whether the store is empty.
    pub fn is_empty(&self) -> bool {
        self.pages.is_empty()
    }

    /// Total compressed bytes stored.
    pub fn stored_bytes(&self) -> usize {
        self.pages.values().map(|p| p.stored_len()).sum()
    }

    /// Total logical bytes stored.
    pub fn logical_bytes(&self) -> usize {
        self.pages.values().map(|p| p.original_len()).sum()
    }

    /// Ids of pages encoded with a version older than `version`.
    pub fn lagging_pages(&self, version: u64) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .pages
            .iter()
            .filter(|(_, p)| p.codec_version() < version)
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        ids
    }

    fn page(&self, page_id: u64) -> Result<&StoredPage> {
        self.pages
            .get(&page_id)
            .ok_or_else(|| Error::Corrupt(format!("page {page_id} not found")))
    }

    /// Decompress a whole page (each frame carries its own codec, so
    /// any published version decodes).
    pub fn read(&self, page_id: u64) -> Result<Vec<u8>> {
        self.page(page_id)?.frame.decompress()
    }

    /// Decompress a whole page into `out`, reusing its allocation — the
    /// zero-allocation loop shape for page sweeps
    /// (`tests/alloc_counting.rs` pins it).
    pub fn read_into(&self, page_id: u64, out: &mut Vec<u8>) -> Result<()> {
        self.page(page_id)?.frame.decompress_into(out)
    }

    /// Decode one block of a page into `out[..len]`; returns the bytes
    /// written. O(1) in the page size, allocation-free.
    pub fn read_block(&self, page_id: u64, block: usize, out: &mut [u8]) -> Result<usize> {
        self.page(page_id)?.frame.read_block(block, out)
    }

    /// Recompress one block of a page in place from `data` (exactly the
    /// block's logical length). Spilled writes accumulate patch-region
    /// garbage; once a page's patch bytes exceed half its footprint the
    /// frame is compacted, so storage accounting stays bounded under
    /// sustained write traffic.
    pub fn write_block(&mut self, page_id: u64, block: usize, data: &[u8]) -> Result<BlockWrite> {
        let page = self
            .pages
            .get_mut(&page_id)
            .ok_or_else(|| Error::Corrupt(format!("page {page_id} not found")))?;
        let wr = page.frame.write_block(block, data, &mut self.scratch)?;
        if page.frame.patch_len() * 2 > page.frame.compressed_len() {
            page.frame.compact();
        }
        Ok(wr)
    }

    /// Drop codec versions no page references anymore (except the newest
    /// `keep` versions). Returns how many were dropped.
    pub fn gc_codecs(&mut self, keep: usize) -> usize {
        let referenced: std::collections::BTreeSet<u64> =
            self.pages.values().map(|p| p.codec_version()).collect();
        let mut versions: Vec<u64> = self.codecs.keys().copied().collect();
        versions.sort_unstable();
        let keep_from = versions.len().saturating_sub(keep);
        let mut dropped = 0;
        for (i, v) in versions.into_iter().enumerate() {
            if i < keep_from && !referenced.contains(&v) {
                self.codecs.remove(&v);
                dropped += 1;
            }
        }
        dropped
    }
}

/// One shard's mutable state: its slice of the page map plus the
/// scratch buffers the block-write path reuses under the shard lock.
struct PageShard {
    pages: HashMap<u64, StoredPage>,
    scratch: Scratch,
}

impl Default for PageShard {
    fn default() -> Self {
        PageShard { pages: HashMap::new(), scratch: Scratch::new() }
    }
}

/// A shard: independently locked state + its hot-path counters, plus an
/// optional hot-block cache. The cache sits behind its own mutex,
/// acquired strictly *before* the state lock — the eviction path holds
/// the cache mutex while flushing deferred writes under the state lock.
struct Shard {
    state: RwLock<PageShard>,
    metrics: ShardMetrics,
    cache: Option<Mutex<BlockCache>>,
}

/// The concurrent page store: N independently locked shards with
/// page-id hash routing, sharing one codec ring (DESIGN.md §8).
///
/// Every method takes `&self`: operations on pages in different shards
/// run fully in parallel, readers of the same shard run in parallel
/// (per-shard `RwLock`), and only writers to the *same shard* serialize.
/// The codec ring sits behind its own lock, so publishing a swapped-in
/// table version is one O(1) insert — shards read codecs through the
/// shared `Arc`s and never copy the ring.
///
/// Semantics are observationally identical to [`PageStore`] (same
/// compaction policy, same error surface); `tests/sharded_store.rs`
/// pins the equivalence under randomized operation interleavings for
/// N ∈ {1, 2, 7}.
///
/// ```
/// use gbdi::coordinator::{ShardedPageStore, StoredPage};
/// use gbdi::{BlockCodec, CodecKind, Frame, GbdiConfig};
/// use std::sync::Arc;
///
/// let image = vec![0u8; 4096];
/// let codec: Arc<dyn BlockCodec> =
///     Arc::from(CodecKind::Gbdi.build_for_image(&image, &GbdiConfig::default()));
/// let store = ShardedPageStore::new(4);
/// store.publish_codec(Arc::clone(&codec));
/// store.put(7, StoredPage { frame: Frame::compress(Arc::clone(&codec), &image) });
/// assert_eq!(store.read(7).unwrap(), image);
/// let mut line = [0u8; 64];
/// store.write_block(7, 3, &[9u8; 64]).unwrap();
/// assert_eq!(store.read_block(7, 3, &mut line).unwrap(), 64);
/// assert_eq!(line, [9u8; 64]);
/// ```
pub struct ShardedPageStore {
    /// The shard set sits behind one outer `RwLock` so
    /// [`Self::resize_shards`] can swap the topology online: every
    /// operation takes the read side for its duration (uncontended in
    /// steady state), a resize takes the write side and so runs exactly
    /// when no operation is in flight. Inside the guard, routing uses
    /// [`Self::route`] with the guard's own length — never a re-entrant
    /// read acquisition, which could deadlock behind a queued resize.
    shards: RwLock<Vec<Shard>>,
    codecs: RwLock<HashMap<u64, Arc<dyn BlockCodec>>>,
    /// Compact a frame once its patch region dominates its footprint
    /// (the serving default). The memory simulator opts out: compaction
    /// rebuilds frames *tight*, which would silently discard the
    /// sector-alignment slack its hardware model depends on.
    auto_compact: bool,
    /// Total cache budget [`Self::with_cache`] was given — remembered so
    /// a resize can re-split it across the new shard count.
    cache_bytes: usize,
}

impl ShardedPageStore {
    /// Empty store with `shards` shards (clamped to at least 1). The
    /// hot-block cache is off; opt in with [`Self::with_cache`].
    pub fn new(shards: usize) -> Self {
        ShardedPageStore {
            shards: RwLock::new(
                (0..shards.max(1))
                    .map(|_| Shard {
                        state: RwLock::new(PageShard::default()),
                        metrics: ShardMetrics::new(),
                        cache: None,
                    })
                    .collect(),
            ),
            codecs: RwLock::new(HashMap::new()),
            auto_compact: true,
            cache_bytes: 0,
        }
    }

    /// Disable the automatic patch-compaction policy (consuming
    /// builder; call at construction, before the store is shared).
    /// Writes then never rebuild a frame's layout behind the caller's
    /// back — the memory simulator uses this to keep its sector-aligned
    /// spans intact, at the cost of unbounded patch growth under
    /// sustained writes.
    pub fn without_auto_compact(mut self) -> Self {
        self.auto_compact = false;
        self
    }

    /// Attach a hot-block cache tier of `total_bytes`, split evenly
    /// across the shards (consuming builder; call at construction,
    /// before the store is shared). `0` leaves the cache off — every
    /// code path then behaves byte-identically to a cacheless store.
    pub fn with_cache(mut self, total_bytes: usize) -> Self {
        self.cache_bytes = total_bytes;
        let shards = self.shards.get_mut().unwrap();
        let n = shards.len();
        for shard in shards.iter_mut() {
            shard.cache = if total_bytes == 0 {
                None
            } else {
                // clamp so even a tiny budget holds at least a few
                // 64-byte blocks per shard instead of thrashing
                Some(Mutex::new(BlockCache::new((total_bytes / n).max(256))))
            };
        }
        self
    }

    /// Whether the hot-block cache tier is on.
    pub fn cache_enabled(&self) -> bool {
        self.cache_bytes > 0
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.read().unwrap().len()
    }

    /// Which shard of `n` a page id routes to: a Fibonacci
    /// multiplicative hash so dense sequential ids still spread evenly,
    /// reduced mod N (N need not be a power of two). Internal code calls
    /// this with the length of an already-held shards guard; re-entering
    /// [`Self::shard_of`] under a guard could deadlock behind a queued
    /// resize.
    fn route(page_id: u64, n: usize) -> usize {
        ((page_id.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) % n as u64) as usize
    }

    /// Which shard a page id routes to under the current topology.
    pub fn shard_of(&self, page_id: u64) -> usize {
        Self::route(page_id, self.shards.read().unwrap().len())
    }

    // ---- codec ring ------------------------------------------------------

    /// Publish a codec version (idempotent; versions are immutable). One
    /// O(1) insert into the shared ring — never an O(shards) fan-out.
    pub fn publish_codec(&self, codec: Arc<dyn BlockCodec>) {
        self.codecs.write().unwrap().entry(codec.version()).or_insert(codec);
    }

    /// Look up a published codec version (cloned `Arc`).
    pub fn codec(&self, version: u64) -> Option<Arc<dyn BlockCodec>> {
        self.codecs.read().unwrap().get(&version).cloned()
    }

    /// Number of published codec versions.
    pub fn codec_count(&self) -> usize {
        self.codecs.read().unwrap().len()
    }

    /// Drop codec versions no page references anymore (except the newest
    /// `keep` versions). Returns how many were dropped. Safe even if a
    /// racing `put` lands a page under an old version: frames carry
    /// their own codec `Arc`, so decode never depends on ring membership.
    pub fn gc_codecs(&self, keep: usize) -> usize {
        let mut referenced = std::collections::BTreeSet::new();
        let shards = self.shards.read().unwrap();
        for shard in shards.iter() {
            let state = shard.state.read().unwrap();
            referenced.extend(state.pages.values().map(|p| p.codec_version()));
        }
        drop(shards);
        let mut ring = self.codecs.write().unwrap();
        let mut versions: Vec<u64> = ring.keys().copied().collect();
        versions.sort_unstable();
        let keep_from = versions.len().saturating_sub(keep);
        let mut dropped = 0;
        for (i, v) in versions.into_iter().enumerate() {
            if i < keep_from && !referenced.contains(&v) {
                ring.remove(&v);
                dropped += 1;
            }
        }
        dropped
    }

    // ---- writes ----------------------------------------------------------

    /// Insert/overwrite a page (one exclusive acquisition of its shard).
    /// Overwriting drops any cached blocks of the page — including
    /// deferred writes, which the fresh page image supersedes.
    pub fn put(&self, page_id: u64, page: StoredPage) {
        debug_assert!(
            self.codecs.read().unwrap().contains_key(&page.codec_version()),
            "page references unpublished codec v{}",
            page.codec_version()
        );
        let shards = self.shards.read().unwrap();
        let shard = &shards[Self::route(page_id, shards.len())];
        let mut cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
        let mut state = shard.state.write().unwrap();
        let t0 = Instant::now();
        if let Some(cache) = cache.as_deref_mut() {
            cache.invalidate_page(page_id);
        }
        state.pages.insert(page_id, page);
        shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
    }

    /// Insert a batch of pages, grouping them per shard so each shard's
    /// lock is taken **once per batch** instead of once per page — the
    /// ingest path the batched submit feeds.
    pub fn put_batch(&self, pages: Vec<(u64, StoredPage)>) {
        #[cfg(debug_assertions)]
        {
            let ring = self.codecs.read().unwrap();
            for (_, p) in &pages {
                debug_assert!(
                    ring.contains_key(&p.codec_version()),
                    "page references unpublished codec v{}",
                    p.codec_version()
                );
            }
        }
        let shards = self.shards.read().unwrap();
        let n = shards.len();
        let mut by_shard: Vec<Vec<(u64, StoredPage)>> = (0..n).map(|_| Vec::new()).collect();
        for (id, page) in pages {
            by_shard[Self::route(id, n)].push((id, page));
        }
        for (idx, group) in by_shard.into_iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let shard = &shards[idx];
            let mut cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
            let mut state = shard.state.write().unwrap();
            let t0 = Instant::now();
            for (id, page) in group {
                if let Some(cache) = cache.as_deref_mut() {
                    cache.invalidate_page(id);
                }
                state.pages.insert(id, page);
            }
            shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
        }
    }

    /// Remove a page (returns it). Deferred cached writes are folded
    /// into the page first, so the caller receives the latest content;
    /// all cached blocks of the page are dropped.
    pub fn remove(&self, page_id: u64) -> Option<StoredPage> {
        let shards = self.shards.read().unwrap();
        let shard = &shards[Self::route(page_id, shards.len())];
        let mut cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
        let mut state = shard.state.write().unwrap();
        let t0 = Instant::now();
        if let Some(cache) = cache.as_deref_mut() {
            let dirty = cache.dirty_blocks_of_page(page_id);
            if !dirty.is_empty() {
                let PageShard { pages, scratch } = &mut *state;
                if let Some(page) = pages.get_mut(&page_id) {
                    for b in &dirty {
                        if let Some(data) = cache.data_of((page_id, *b)) {
                            // cached blocks always index valid blocks of
                            // a live frame, so this cannot fail; a
                            // corrupt frame surfaces on the next read
                            let _ = page.frame.write_block(*b as usize, data, scratch);
                        }
                    }
                    shard.metrics.deferred_flushed(dirty.len() as u64);
                }
            }
            cache.invalidate_page(page_id);
        }
        let removed = state.pages.remove(&page_id);
        shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
        removed
    }

    /// Recompress one block of a page in place from `data` (exactly the
    /// block's logical length), under this shard's lock with its own
    /// scratch. Same compaction policy as [`PageStore::write_block`]
    /// unless disabled via [`Self::without_auto_compact`]: once patch
    /// bytes exceed half the frame's footprint it compacts, so storage
    /// stays bounded under sustained write traffic.
    pub fn write_block(&self, page_id: u64, block: usize, data: &[u8]) -> Result<BlockWrite> {
        self.write_block_observed(page_id, block, data).map(|(_, wr)| wr)
    }

    /// [`Self::write_block`] that also reports the block's encoded bits
    /// *before* the write, all under one lock acquisition — the memory
    /// simulator's sector accounting needs the before/after pair and
    /// must not pay two shard lookups per simulated write.
    pub fn write_block_observed(
        &self,
        page_id: u64,
        block: usize,
        data: &[u8],
    ) -> Result<(u32, BlockWrite)> {
        let shards = self.shards.read().unwrap();
        let shard = &shards[Self::route(page_id, shards.len())];
        let t0 = Instant::now();
        let r = match &shard.cache {
            None => self.write_block_frame(shard, page_id, block, data),
            Some(cache) => self.write_block_via_cache(shard, cache, page_id, block, data),
        };
        if r.is_ok() {
            shard.metrics.block_write(t0.elapsed().as_nanos() as u64);
        }
        r
    }

    /// The cacheless write path: recompress the block in the frame
    /// under the shard's exclusive lock (records lock-hold time, not the
    /// block-write counter — the caller owns that).
    fn write_block_frame(
        &self,
        shard: &Shard,
        page_id: u64,
        block: usize,
        data: &[u8],
    ) -> Result<(u32, BlockWrite)> {
        let mut state = shard.state.write().unwrap();
        let held = Instant::now();
        let r = {
            let PageShard { pages, scratch } = &mut *state;
            match pages.get_mut(&page_id) {
                Some(page) => {
                    // out-of-range blocks fall through to the
                    // frame's own range error below
                    let old = if block < page.frame.n_blocks() {
                        page.frame.block_bits(block)
                    } else {
                        0
                    };
                    let wr = page.frame.write_block(block, data, scratch);
                    if wr.is_ok()
                        && self.auto_compact
                        && page.frame.patch_len() * 2 > page.frame.compressed_len()
                    {
                        page.frame.compact();
                    }
                    wr.map(|wr| (old, wr))
                }
                None => Err(Error::Corrupt(format!("page {page_id} not found"))),
            }
        };
        shard.metrics.lock_hold(held.elapsed().as_nanos() as u64);
        r
    }

    /// The cached write path. A write to a *resident* block is absorbed:
    /// the cached copy is updated and marked dirty, the frame keeps its
    /// stale encoding until the block cools out of the cache (deferred
    /// recompression), and the reported [`BlockWrite`] carries the
    /// frame's current bits with `spilled: false` — no framing changed.
    /// A write to a cold block goes through the frame as usual, then the
    /// fresh copy is admitted so a write-hot block's *next* write defers.
    fn write_block_via_cache(
        &self,
        shard: &Shard,
        cache: &Mutex<BlockCache>,
        page_id: u64,
        block: usize,
        data: &[u8],
    ) -> Result<(u32, BlockWrite)> {
        let key = (page_id, block as u32);
        let mut cache = cache.lock().unwrap();
        if let Some(cached) = cache.cached_len(key) {
            if data.len() != cached {
                return Err(Error::Config(format!(
                    "write must supply exactly {cached} B for block {block}, got {}",
                    data.len()
                )));
            }
            cache.absorb_write(key, data);
            shard.metrics.cache_hit();
            let state = shard.state.read().unwrap();
            let bits = match state.pages.get(&page_id) {
                Some(p) if block < p.frame.n_blocks() => p.frame.block_bits(block),
                _ => 0,
            };
            return Ok((bits, BlockWrite { bits, spilled: false }));
        }
        let r = self.write_block_frame(shard, page_id, block, data)?;
        shard.metrics.cache_miss();
        let evicted = cache.insert(key, data.to_vec(), false, false);
        shard.metrics.cache_admission();
        self.flush_evicted(shard, evicted)?;
        Ok(r)
    }

    /// Write the deferred (dirty) blocks the cache pushed out back
    /// through their frames, under the shard's exclusive lock. Called
    /// with the shard's cache mutex held (lock order: cache, then state).
    fn flush_evicted(&self, shard: &Shard, evicted: Vec<EvictedBlock>) -> Result<()> {
        if evicted.is_empty() {
            return Ok(());
        }
        shard.metrics.cache_evicted(evicted.len() as u64);
        let dirty: Vec<EvictedBlock> = evicted.into_iter().filter(|e| e.dirty).collect();
        if dirty.is_empty() {
            return Ok(());
        }
        let mut state = shard.state.write().unwrap();
        let t0 = Instant::now();
        let r = {
            let PageShard { pages, scratch } = &mut *state;
            let mut out = Ok(());
            for ev in &dirty {
                // invariant: a cached entry's page is live (remove/put
                // invalidate under the cache mutex we are holding)
                let Some(page) = pages.get_mut(&ev.page_id) else {
                    out = Err(Error::Corrupt(format!("page {} not found", ev.page_id)));
                    break;
                };
                if let Err(e) = page.frame.write_block(ev.block as usize, &ev.data, scratch) {
                    out = Err(e);
                    break;
                }
                if self.auto_compact && page.frame.patch_len() * 2 > page.frame.compressed_len() {
                    page.frame.compact();
                }
            }
            out
        };
        shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
        if r.is_ok() {
            shard.metrics.deferred_flushed(dirty.len() as u64);
        }
        r
    }

    /// Migrate up to `max_pages` pages of shard `idx` that are encoded
    /// under a version older than `codec.version()`, re-encoding them
    /// under `codec`. The shard lock is dropped between pages, so
    /// foreground GETs/PUTs on this shard interleave with maintenance —
    /// and other shards never see the migration at all. Each page's
    /// decode + re-encode happens under the exclusive guard, so a block
    /// PUT can never be clobbered by a stale re-encode. Returns the
    /// pages migrated.
    pub fn migrate_shard(
        &self,
        idx: usize,
        codec: &Arc<dyn BlockCodec>,
        max_pages: usize,
    ) -> Result<usize> {
        let target = codec.version();
        let shards = self.shards.read().unwrap();
        // a racing resize may have shrunk the topology since the caller
        // snapshotted shard_count(); those pages now live elsewhere
        let Some(shard) = shards.get(idx) else { return Ok(0) };
        let mut lagging: Vec<u64> = {
            let state = shard.state.read().unwrap();
            state
                .pages
                .iter()
                .filter(|(_, p)| p.codec_version() < target)
                .map(|(&id, _)| id)
                .collect()
        };
        lagging.sort_unstable();
        lagging.truncate(max_pages);
        let mut moved = 0;
        for id in lagging {
            let mut cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
            let mut state = shard.state.write().unwrap();
            let t0 = Instant::now();
            {
                let PageShard { pages, scratch } = &mut *state;
                // re-check under the exclusive guard: the page may have
                // been removed or already migrated since the snapshot
                if let Some(page) = pages.get_mut(&id) {
                    if page.codec_version() < target {
                        // fold deferred cached writes into the frame
                        // first, or the re-encode would resurrect stale
                        // content; clean cached copies stay valid since
                        // the logical content does not change
                        if let Some(cache) = cache.as_deref_mut() {
                            let dirty = cache.dirty_blocks_of_page(id);
                            for b in &dirty {
                                if let Some(data) = cache.data_of((id, *b)) {
                                    page.frame.write_block(*b as usize, data, scratch)?;
                                }
                            }
                            for b in &dirty {
                                cache.mark_clean((id, *b));
                            }
                            if !dirty.is_empty() {
                                shard.metrics.deferred_flushed(dirty.len() as u64);
                            }
                        }
                        let data = page.frame.decompress()?;
                        page.frame = Frame::compress_with(Arc::clone(codec), &data, scratch);
                        moved += 1;
                    }
                }
            }
            shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
        }
        Ok(moved)
    }

    // ---- reads -----------------------------------------------------------

    /// Run `f` on a stored page under the shard's read lock (metadata
    /// inspection without copying the page out).
    pub fn with_page<R>(&self, page_id: u64, f: impl FnOnce(&StoredPage) -> R) -> Option<R> {
        let shards = self.shards.read().unwrap();
        let state = shards[Self::route(page_id, shards.len())].state.read().unwrap();
        state.pages.get(&page_id).map(f)
    }

    /// Whether a page is stored.
    pub fn contains(&self, page_id: u64) -> bool {
        let shards = self.shards.read().unwrap();
        let state = shards[Self::route(page_id, shards.len())].state.read().unwrap();
        state.pages.contains_key(&page_id)
    }

    /// Decompress a whole page (each frame carries its own codec, so any
    /// published version decodes). With the cache on, deferred cached
    /// writes are overlaid so the caller always sees the latest content.
    pub fn read(&self, page_id: u64) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        self.read_into(page_id, &mut out)?;
        Ok(out)
    }

    /// Decompress a whole page into `out`, reusing its allocation — the
    /// zero-allocation loop shape for page sweeps
    /// (`tests/alloc_counting.rs` pins it). Deferred cached writes are
    /// overlaid, same as [`Self::read`].
    pub fn read_into(&self, page_id: u64, out: &mut Vec<u8>) -> Result<()> {
        let shards = self.shards.read().unwrap();
        let shard = &shards[Self::route(page_id, shards.len())];
        let cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
        let state = shard.state.read().unwrap();
        let p = match state.pages.get(&page_id) {
            Some(p) => p,
            None => return Err(Error::Corrupt(format!("page {page_id} not found"))),
        };
        p.frame.decompress_into(out)?;
        if let Some(cache) = &cache {
            let bb = p.frame.block_bytes();
            for b in cache.dirty_blocks_of_page(page_id) {
                if let Some(data) = cache.data_of((page_id, b)) {
                    let off = b as usize * bb;
                    out[off..off + data.len()].copy_from_slice(data);
                }
            }
        }
        Ok(())
    }

    /// Decode one block of a page into `out[..len]`; returns the bytes
    /// written. O(1) in the page size, allocation-free, and concurrent
    /// with every read on this shard (shared lock side). With the cache
    /// on, a resident block is copied straight out of uncompressed
    /// cache memory — zero decode, zero allocation.
    pub fn read_block(&self, page_id: u64, block: usize, out: &mut [u8]) -> Result<usize> {
        let shards = self.shards.read().unwrap();
        let shard = &shards[Self::route(page_id, shards.len())];
        let t0 = Instant::now();
        let r = match &shard.cache {
            None => {
                let state = shard.state.read().unwrap();
                match state.pages.get(&page_id) {
                    Some(p) => p.frame.read_block(block, out),
                    None => Err(Error::Corrupt(format!("page {page_id} not found"))),
                }
            }
            Some(cache) => self.read_block_via_cache(shard, cache, page_id, block, out),
        };
        if r.is_ok() {
            shard.metrics.block_read(t0.elapsed().as_nanos() as u64);
        }
        r
    }

    /// The cached read path: serve hits from cache memory; on a miss,
    /// decode under the shard's read lock and admit the block. Admission
    /// is latency-driven: a miss whose decode cost at least matches the
    /// shard's running mean block-read latency skips probation
    /// (expensive-to-decode blocks are exactly the ones worth keeping
    /// uncompressed), as does any block still remembered by the ghost
    /// history.
    fn read_block_via_cache(
        &self,
        shard: &Shard,
        cache: &Mutex<BlockCache>,
        page_id: u64,
        block: usize,
        out: &mut [u8],
    ) -> Result<usize> {
        let key = (page_id, block as u32);
        let mut cache = cache.lock().unwrap();
        if let Some(data) = cache.get(key) {
            let n = data.len();
            if out.len() < n {
                return Err(Error::Config(format!(
                    "output buffer {} B short of block length {n} B",
                    out.len()
                )));
            }
            out[..n].copy_from_slice(data);
            shard.metrics.cache_hit();
            return Ok(n);
        }
        // miss: decode under the state read lock. The cache mutex stays
        // held, so a racing remove/put cannot invalidate the page
        // between this decode and the admission below.
        let d0 = Instant::now();
        let n = {
            let state = shard.state.read().unwrap();
            match state.pages.get(&page_id) {
                Some(p) => p.frame.read_block(block, out)?,
                None => return Err(Error::Corrupt(format!("page {page_id} not found"))),
            }
        };
        let decode_ns = d0.elapsed().as_nanos() as u64;
        shard.metrics.cache_miss();
        let mean = shard.metrics.block_read_mean_ns();
        let hot = mean > 0.0 && decode_ns as f64 >= mean;
        let evicted = cache.insert(key, out[..n].to_vec(), false, hot);
        shard.metrics.cache_admission();
        self.flush_evicted(shard, evicted)?;
        Ok(n)
    }

    /// Current exact encoding length of one block of a page, in bits
    /// (the memory simulator's sector accounting reads this). This is
    /// the *compressed tier's* truth: a deferred cached write does not
    /// change it until the block is flushed.
    pub fn block_bits(&self, page_id: u64, block: usize) -> Result<u32> {
        let shards = self.shards.read().unwrap();
        let state = shards[Self::route(page_id, shards.len())].state.read().unwrap();
        match state.pages.get(&page_id) {
            Some(p) if block < p.frame.n_blocks() => Ok(p.frame.block_bits(block)),
            Some(p) => Err(Error::Config(format!(
                "block {block} out of range ({} blocks)",
                p.frame.n_blocks()
            ))),
            None => Err(Error::Corrupt(format!("page {page_id} not found"))),
        }
    }

    // ---- accounting ------------------------------------------------------

    /// Number of stored pages (sums the shards; not an atomic snapshot
    /// under concurrent writers, like any aggregate here).
    pub fn len(&self) -> usize {
        self.shards.read().unwrap().iter().map(|s| s.state.read().unwrap().pages.len()).sum()
    }

    /// Whether the store is empty.
    pub fn is_empty(&self) -> bool {
        self.shards.read().unwrap().iter().all(|s| s.state.read().unwrap().pages.is_empty())
    }

    /// Total physical bytes stored: compressed frames plus any
    /// uncompressed bytes resident in the hot-block cache — the honest
    /// numerator, so compression-ratio reporting cannot flatter itself
    /// by ignoring the cache tier.
    pub fn stored_bytes(&self) -> usize {
        self.shards
            .read()
            .unwrap()
            .iter()
            .map(|s| {
                let cache = s.cache.as_ref().map(|c| c.lock().unwrap());
                let frames = s
                    .state
                    .read()
                    .unwrap()
                    .pages
                    .values()
                    .map(|p| p.stored_len())
                    .sum::<usize>();
                frames + cache.map_or(0, |c| c.resident_bytes())
            })
            .sum()
    }

    /// Total logical bytes stored.
    pub fn logical_bytes(&self) -> usize {
        self.shards
            .read()
            .unwrap()
            .iter()
            .map(|s| {
                s.state.read().unwrap().pages.values().map(|p| p.original_len()).sum::<usize>()
            })
            .sum()
    }

    /// `(logical_bytes, stored_bytes)` in one sweep: each shard's
    /// contribution is read under a single lock acquisition, so the two
    /// numbers are mutually consistent per shard (and the lock traffic
    /// is half of calling the two accessors separately). Stored bytes
    /// include cache-resident uncompressed data, same as
    /// [`Self::stored_bytes`].
    pub fn usage(&self) -> (usize, usize) {
        let mut logical = 0usize;
        let mut stored = 0usize;
        let shards = self.shards.read().unwrap();
        for shard in shards.iter() {
            let cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
            let state = shard.state.read().unwrap();
            for p in state.pages.values() {
                logical += p.original_len();
                stored += p.stored_len();
            }
            stored += cache.map_or(0, |c| c.resident_bytes());
        }
        (logical, stored)
    }

    /// Uncompressed bytes resident in the hot-block cache across all
    /// shards (0 with the cache off).
    pub fn cache_resident_bytes(&self) -> usize {
        self.shards
            .read()
            .unwrap()
            .iter()
            .map(|s| s.cache.as_ref().map_or(0, |c| c.lock().unwrap().resident_bytes()))
            .sum()
    }

    /// Service-wide cache totals: the sum of the per-shard snapshots.
    pub fn cache_totals(&self) -> CacheTotals {
        CacheTotals::from_shards(&self.shard_metrics())
    }

    /// Flush every deferred (dirty) cached block back through its
    /// frame, leaving the cache resident but clean — shutdown, tests,
    /// and accounting sweeps use this to bring the compressed tier up
    /// to date without evicting the hot set. Returns blocks flushed.
    pub fn flush_cache(&self) -> usize {
        let mut flushed = 0usize;
        let shards = self.shards.read().unwrap();
        for shard in shards.iter() {
            let Some(cache) = &shard.cache else { continue };
            let mut cache = cache.lock().unwrap();
            let dirty_pages = cache.dirty_pages();
            if dirty_pages.is_empty() {
                continue;
            }
            let mut state = shard.state.write().unwrap();
            let t0 = Instant::now();
            let PageShard { pages, scratch } = &mut *state;
            for id in dirty_pages {
                let Some(page) = pages.get_mut(&id) else { continue };
                let dirty = cache.dirty_blocks_of_page(id);
                for b in &dirty {
                    if let Some(data) = cache.data_of((id, *b)) {
                        // cannot fail for a live cached block; a corrupt
                        // frame surfaces on the next read
                        let _ = page.frame.write_block(*b as usize, data, scratch);
                    }
                }
                if self.auto_compact && page.frame.patch_len() * 2 > page.frame.compressed_len() {
                    page.frame.compact();
                }
                for b in &dirty {
                    cache.mark_clean((id, *b));
                }
                shard.metrics.deferred_flushed(dirty.len() as u64);
                flushed += dirty.len();
            }
            shard.metrics.lock_hold(t0.elapsed().as_nanos() as u64);
        }
        flushed
    }

    /// Ids of pages encoded with a version older than `version`, across
    /// all shards, sorted.
    pub fn lagging_pages(&self, version: u64) -> Vec<u64> {
        let mut ids = Vec::new();
        let shards = self.shards.read().unwrap();
        for shard in shards.iter() {
            let state = shard.state.read().unwrap();
            ids.extend(
                state
                    .pages
                    .iter()
                    .filter(|(_, p)| p.codec_version() < version)
                    .map(|(&id, _)| id),
            );
        }
        ids.sort_unstable();
        ids
    }

    /// Per-shard metrics: occupancy gauges read under each shard's read
    /// lock (and cache mutex) plus the wait-free counters. Counter sums
    /// equal the service-wide totals (both sides count each successful
    /// op once). `stored_bytes` includes cache-resident bytes, matching
    /// [`Self::usage`].
    pub fn shard_metrics(&self) -> Vec<ShardMetricsSnapshot> {
        self.shards
            .read()
            .unwrap()
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let cache = shard.cache.as_ref().map(|c| c.lock().unwrap());
                let gauges = cache.as_ref().map_or(CacheGauges::default(), |c| CacheGauges {
                    blocks: c.resident_blocks() as u64,
                    bytes: c.resident_bytes() as u64,
                    dirty_blocks: c.dirty_blocks() as u64,
                    dirty_bytes: c.dirty_bytes() as u64,
                });
                let state = shard.state.read().unwrap();
                let pages = state.pages.len() as u64;
                let logical =
                    state.pages.values().map(|p| p.original_len() as u64).sum::<u64>();
                let stored = state.pages.values().map(|p| p.stored_len() as u64).sum::<u64>()
                    + gauges.bytes;
                shard.metrics.snapshot(i, pages, logical, stored, gauges)
            })
            .collect()
    }

    // ---- elasticity + persistence export ---------------------------------

    /// Resize the store to `new_n` shards **online**: takes the outer
    /// write lock (so it runs exactly when no operation is in flight —
    /// concurrent GETs/PUTs simply queue for the duration), folds every
    /// deferred cached write into its frame, reroutes all pages under
    /// the new topology, and re-splits the cache budget. Per-shard
    /// metrics counters move with surviving shard indices; counters of
    /// retired shards are folded into shard 0, so sums over shards still
    /// equal the service-wide totals. Returns how many pages changed
    /// shard.
    pub fn resize_shards(&self, new_n: usize) -> usize {
        let new_n = new_n.max(1);
        let mut shards = self.shards.write().unwrap();
        let old_n = shards.len();
        if old_n == new_n {
            return 0;
        }
        // exclusive access: get_mut everywhere, no inner locking
        let mut all: Vec<(u64, StoredPage)> = Vec::new();
        for shard in shards.iter_mut() {
            let Shard { state, metrics, cache } = shard;
            let state = state.get_mut().unwrap();
            if let Some(cache) = cache {
                let cache = cache.get_mut().unwrap();
                let PageShard { pages, scratch } = state;
                for id in cache.dirty_pages() {
                    let Some(page) = pages.get_mut(&id) else { continue };
                    let dirty = cache.dirty_blocks_of_page(id);
                    for b in &dirty {
                        if let Some(data) = cache.data_of((id, *b)) {
                            // cached blocks index valid blocks of a live
                            // frame; a corrupt frame surfaces on read
                            let _ = page.frame.write_block(*b as usize, data, scratch);
                        }
                    }
                    if self.auto_compact
                        && page.frame.patch_len() * 2 > page.frame.compressed_len()
                    {
                        page.frame.compact();
                    }
                    metrics.deferred_flushed(dirty.len() as u64);
                }
            }
            all.extend(state.pages.drain());
        }
        let moved = all
            .iter()
            .filter(|(id, _)| Self::route(*id, old_n) != Self::route(*id, new_n))
            .count();
        let mut old_metrics: Vec<ShardMetrics> =
            std::mem::take(&mut *shards).into_iter().map(|s| s.metrics).collect();
        let mut rebuilt: Vec<Shard> = (0..new_n)
            .map(|i| Shard {
                state: RwLock::new(PageShard::default()),
                metrics: if i < old_metrics.len() {
                    std::mem::replace(&mut old_metrics[i], ShardMetrics::new())
                } else {
                    ShardMetrics::new()
                },
                cache: if self.cache_bytes > 0 {
                    Some(Mutex::new(BlockCache::new((self.cache_bytes / new_n).max(256))))
                } else {
                    None
                },
            })
            .collect();
        for retired in old_metrics.into_iter().skip(new_n) {
            rebuilt[0].metrics.absorb(&retired);
        }
        for (id, page) in all {
            let idx = Self::route(id, new_n);
            rebuilt[idx].state.get_mut().unwrap().pages.insert(id, page);
        }
        *shards = rebuilt;
        moved
    }

    /// Every published codec version, sorted by version — the checkpoint
    /// writer snapshots these into the manifest.
    pub fn codecs(&self) -> Vec<Arc<dyn BlockCodec>> {
        let mut v: Vec<Arc<dyn BlockCodec>> =
            self.codecs.read().unwrap().values().cloned().collect();
        v.sort_by_key(|c| c.version());
        v
    }

    /// Serialize one shard's pages as `(page_id, GBC1 container bytes)`,
    /// sorted by page id for deterministic segment files. The caller
    /// (the checkpoint writer) flushes the block cache first so frames
    /// hold the complete logical state. An out-of-range index (racing
    /// resize) yields an empty export.
    pub fn export_shard(&self, idx: usize) -> Vec<(u64, Vec<u8>)> {
        let shards = self.shards.read().unwrap();
        let Some(shard) = shards.get(idx) else { return Vec::new() };
        let state = shard.state.read().unwrap();
        let mut out: Vec<(u64, Vec<u8>)> = state
            .pages
            .iter()
            .map(|(&id, p)| (id, p.frame.to_container().to_bytes()))
            .collect();
        out.sort_unstable_by_key(|(id, _)| *id);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdi::{analyze, table::GlobalBaseTable, GbdiCodec, GbdiConfig};
    use crate::value::WordSize;
    use crate::workloads;

    fn compress_page(data: &[u8], codec: &Arc<dyn BlockCodec>) -> StoredPage {
        StoredPage { frame: Frame::compress(Arc::clone(codec), data) }
    }

    #[test]
    fn pages_survive_codec_swaps() {
        let cfg = GbdiConfig::default();
        let img_a = workloads::by_name("mcf").unwrap().generate(4096, 1);
        let img_b = workloads::by_name("svm").unwrap().generate(4096, 1);
        let mut t1 = analyze::analyze_image(&img_a, &cfg);
        t1.version = 1;
        let mut t2 = analyze::analyze_image(&img_b, &cfg);
        t2.version = 2;
        let c1: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t1, cfg.clone()));
        let c2: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t2, cfg.clone()));

        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&c1));
        store.put(10, compress_page(&img_a, &c1));
        store.publish_codec(Arc::clone(&c2));
        store.put(20, compress_page(&img_b, &c2));

        // both decode bit-exactly despite different codec versions
        assert_eq!(store.read(10).unwrap(), img_a);
        assert_eq!(store.read(20).unwrap(), img_b);
        assert_eq!(store.lagging_pages(2), vec![10]);
        assert_eq!(store.lagging_pages(1), Vec::<u64>::new());
    }

    #[test]
    fn block_reads_and_writes_hit_frames_not_pages() {
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("mcf").unwrap().generate(4096, 9);
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        // single-block GET matches the image slice
        let mut buf = [0u8; 64];
        for i in [0usize, 7, 63] {
            let n = store.read_block(1, i, &mut buf).unwrap();
            assert_eq!(&buf[..n], &img[i * 64..(i + 1) * 64]);
        }
        // single-block PUT is visible to both block and page reads
        let line = [0x5Au8; 64];
        store.write_block(1, 5, &line).unwrap();
        let n = store.read_block(1, 5, &mut buf).unwrap();
        assert_eq!(&buf[..n], &line[..]);
        let mut expect = img.clone();
        expect[5 * 64..6 * 64].copy_from_slice(&line);
        assert_eq!(store.read(1).unwrap(), expect);
        // out-of-range accesses error
        assert!(store.read_block(1, 64, &mut buf).is_err());
        assert!(store.read_block(99, 0, &mut buf).is_err());
        assert!(store.write_block(99, 0, &line).is_err());
    }

    #[test]
    fn sustained_block_writes_keep_storage_bounded() {
        // growth-spill garbage must not accumulate without bound: the
        // store compacts a frame once patch bytes dominate its footprint
        let cfg = GbdiConfig::default();
        let img = vec![0u8; 4096];
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        let mut rng = crate::util::prng::Rng::new(5);
        let mut noisy = [0u8; 64];
        let mut expect = img.clone();
        for round in 0..200 {
            let blk = (round * 7) % 64;
            if round % 3 == 2 {
                noisy[..].fill(0);
            } else {
                rng.fill_bytes(&mut noisy);
            }
            store.write_block(1, blk, &noisy).unwrap();
            expect[blk * 64..(blk + 1) * 64].copy_from_slice(&noisy);
        }
        // bound: the page never stores more than ~2x its worst-case raw
        // footprint (64 raw blocks + framing), however many spills happened
        let stored = store.get(1).unwrap().stored_len();
        assert!(stored < 2 * (4096 + 4096 / 64 * 3 + 16), "stored {stored} B unbounded");
        assert_eq!(store.read(1).unwrap(), expect, "content survives compactions");
    }

    #[test]
    fn heterogeneous_codecs_coexist() {
        // the ring is codec-agnostic: a BDI page (version 0) and a GBDI
        // page (version 3) live side by side
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("fluidanimate").unwrap().generate(4096, 2);
        let bdi: Arc<dyn BlockCodec> =
            Arc::new(crate::baselines::bdi::Bdi { block_bytes: cfg.block_bytes });
        let mut t = analyze::analyze_image(&img, &cfg);
        t.version = 3;
        let gbdi: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t, cfg));

        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&bdi));
        store.put(1, compress_page(&img, &bdi));
        store.publish_codec(Arc::clone(&gbdi));
        store.put(2, compress_page(&img, &gbdi));
        assert_eq!(store.read(1).unwrap(), img);
        assert_eq!(store.read(2).unwrap(), img);
        assert_eq!(store.codec_count(), 2);
    }

    #[test]
    fn missing_page_and_codec_error() {
        let store = PageStore::new();
        assert!(store.read(99).is_err());
    }

    #[test]
    fn gc_keeps_referenced_versions() {
        let cfg = GbdiConfig::default();
        let img = vec![7u8; 4096];
        let mut store = PageStore::new();
        for v in 1..=5 {
            let t = GlobalBaseTable::new(vec![(v * 1000, 8)], WordSize::W32, v);
            let codec: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t, cfg.clone()));
            store.publish_codec(Arc::clone(&codec));
            if v == 2 {
                store.put(1, compress_page(&img, &codec));
            }
        }
        let dropped = store.gc_codecs(1);
        // v1, v3, v4 droppable; v2 referenced; v5 newest kept
        assert_eq!(dropped, 3);
        assert!(store.codec(2).is_some());
        assert!(store.codec(5).is_some());
        assert_eq!(store.read(1).unwrap(), img);
    }

    #[test]
    fn accounting() {
        let cfg = GbdiConfig::default();
        let img = vec![0u8; 8192];
        let t = analyze::analyze_image(&img, &cfg);
        let codec: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t, cfg));
        let mut store = PageStore::new();
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        assert_eq!(store.len(), 1);
        assert_eq!(store.logical_bytes(), 8192);
        assert!(store.stored_bytes() < 2048, "zeros compress: {}", store.stored_bytes());
        store.remove(1).unwrap();
        assert!(store.is_empty());
    }

    #[test]
    fn sharded_routing_covers_all_shards_and_is_stable() {
        let store = ShardedPageStore::new(7);
        assert_eq!(store.shard_count(), 7);
        let mut seen = [false; 7];
        for id in 0..512u64 {
            let s = store.shard_of(id);
            assert!(s < 7);
            assert_eq!(s, store.shard_of(id), "routing must be deterministic");
            seen[s] = true;
        }
        assert!(seen.iter().all(|&s| s), "dense ids must spread over every shard");
        // a single shard degenerates to "everything routes to 0"
        let one = ShardedPageStore::new(1);
        assert!((0..100).all(|id| one.shard_of(id) == 0));
        // shard count is clamped to at least one
        assert_eq!(ShardedPageStore::new(0).shard_count(), 1);
    }

    #[test]
    fn sharded_store_serves_pages_and_blocks() {
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("mcf").unwrap().generate(4096, 9);
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(3);
        store.publish_codec(Arc::clone(&codec));
        for id in 0..12u64 {
            store.put(id, compress_page(&img, &codec));
        }
        assert_eq!(store.len(), 12);
        assert!(store.contains(5) && !store.contains(99));
        assert_eq!(store.logical_bytes(), 12 * 4096);
        assert_eq!(store.usage(), (store.logical_bytes(), store.stored_bytes()));
        let mut buf = [0u8; 64];
        for id in [0u64, 5, 11] {
            assert_eq!(store.read(id).unwrap(), img);
            let n = store.read_block(id, 7, &mut buf).unwrap();
            assert_eq!(&buf[..n], &img[7 * 64..8 * 64]);
        }
        // block write lands and block_bits tracks it
        let line = [0x5Au8; 64];
        let wr = store.write_block(3, 5, &line).unwrap();
        assert_eq!(store.block_bits(3, 5).unwrap(), wr.bits);
        let n = store.read_block(3, 5, &mut buf).unwrap();
        assert_eq!(&buf[..n], &line[..]);
        // errors on the right surface
        assert!(store.read(99).is_err());
        assert!(store.read_block(0, 64, &mut buf).is_err());
        assert!(store.write_block(99, 0, &line).is_err());
        assert!(store.block_bits(0, 64).is_err());
        assert!(store.block_bits(99, 0).is_err());
        // metadata inspection without copying
        assert_eq!(store.with_page(0, |p| p.original_len()), Some(4096));
        assert_eq!(store.with_page(99, |p| p.original_len()), None);
        // removal
        assert!(store.remove(0).is_some());
        assert!(store.remove(0).is_none());
        assert_eq!(store.len(), 11);
    }

    #[test]
    fn sharded_put_batch_takes_each_shard_once() {
        let cfg = GbdiConfig::default();
        let img = vec![3u8; 4096];
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(4);
        store.publish_codec(Arc::clone(&codec));
        let batch: Vec<(u64, StoredPage)> =
            (0..64u64).map(|id| (id, compress_page(&img, &codec))).collect();
        store.put_batch(batch);
        assert_eq!(store.len(), 64);
        for id in 0..64u64 {
            assert_eq!(store.read(id).unwrap(), img);
        }
        // each non-empty shard was locked exactly once for the batch
        let snaps = store.shard_metrics();
        assert_eq!(snaps.len(), 4);
        let total_pages: u64 = snaps.iter().map(|s| s.pages).sum();
        assert_eq!(total_pages, 64);
        for s in &snaps {
            if s.pages > 0 {
                assert_eq!(s.lock_holds, 1, "shard {} locked once per batch", s.shard);
            }
        }
        // empty batches are a no-op
        store.put_batch(Vec::new());
        assert_eq!(store.len(), 64);
    }

    #[test]
    fn sharded_migration_walks_one_shard_at_a_time() {
        let cfg = GbdiConfig::default();
        let img_a = workloads::by_name("mcf").unwrap().generate(4096, 1);
        let img_b = workloads::by_name("svm").unwrap().generate(4096, 2);
        let mut t1 = analyze::analyze_image(&img_a, &cfg);
        t1.version = 1;
        let mut t2 = analyze::analyze_image(&img_b, &cfg);
        t2.version = 2;
        let c1: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t1, cfg.clone()));
        let c2: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t2, cfg));
        let store = ShardedPageStore::new(2);
        store.publish_codec(Arc::clone(&c1));
        for id in 0..16u64 {
            store.put(id, compress_page(&img_a, &c1));
        }
        store.publish_codec(Arc::clone(&c2));
        assert_eq!(store.lagging_pages(2).len(), 16);
        // migrate shard by shard under a per-call budget
        let mut moved = 0;
        for shard in 0..store.shard_count() {
            loop {
                let n = store.migrate_shard(shard, &c2, 3).unwrap();
                moved += n;
                if n == 0 {
                    break;
                }
            }
        }
        assert_eq!(moved, 16);
        assert!(store.lagging_pages(2).is_empty());
        for id in 0..16u64 {
            assert_eq!(store.read(id).unwrap(), img_a, "page {id} after migration");
            assert_eq!(store.with_page(id, |p| p.codec_version()), Some(2));
        }
        // a second walk is a no-op
        assert_eq!(store.migrate_shard(0, &c2, 100).unwrap(), 0);
    }

    #[test]
    fn sharded_gc_keeps_referenced_versions() {
        let cfg = GbdiConfig::default();
        let img = vec![7u8; 4096];
        let store = ShardedPageStore::new(3);
        for v in 1..=5 {
            let t = GlobalBaseTable::new(vec![(v * 1000, 8)], WordSize::W32, v);
            let codec: Arc<dyn BlockCodec> = Arc::new(GbdiCodec::new(t, cfg.clone()));
            store.publish_codec(Arc::clone(&codec));
            if v == 2 {
                store.put(1, compress_page(&img, &codec));
            }
        }
        assert_eq!(store.codec_count(), 5);
        let dropped = store.gc_codecs(1);
        // v1, v3, v4 droppable; v2 referenced; v5 newest kept
        assert_eq!(dropped, 3);
        assert!(store.codec(2).is_some());
        assert!(store.codec(5).is_some());
        assert!(store.codec(1).is_none());
        assert_eq!(store.read(1).unwrap(), img);
    }

    #[test]
    fn sharded_sustained_writes_keep_storage_bounded() {
        // same compaction policy as the single-lock store: patch-region
        // garbage must not accumulate without bound
        let cfg = GbdiConfig::default();
        let img = vec![0u8; 4096];
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(2);
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        let mut rng = crate::util::prng::Rng::new(5);
        let mut noisy = [0u8; 64];
        let mut expect = img.clone();
        for round in 0..200 {
            let blk = (round * 7) % 64;
            if round % 3 == 2 {
                noisy[..].fill(0);
            } else {
                rng.fill_bytes(&mut noisy);
            }
            store.write_block(1, blk, &noisy).unwrap();
            expect[blk * 64..(blk + 1) * 64].copy_from_slice(&noisy);
        }
        let stored = store.with_page(1, |p| p.stored_len()).unwrap();
        assert!(stored < 2 * (4096 + 4096 / 64 * 3 + 16), "stored {stored} B unbounded");
        assert_eq!(store.read(1).unwrap(), expect, "content survives compactions");
        // write latencies and lock holds were recorded on page 1's shard
        let snaps = store.shard_metrics();
        let shard = &snaps[store.shard_of(1)];
        assert_eq!(shard.block_writes, 200);
        assert!(shard.block_write_mean_ns() > 0.0);
        assert!(shard.lock_holds >= 200);
        assert!(shard.lock_hold_mean_ns() > 0.0);
    }

    #[test]
    fn cached_store_serves_hits_and_defers_writes() {
        let cfg = GbdiConfig::default();
        let img = workloads::by_name("mcf").unwrap().generate(4096, 9);
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(2).with_cache(1 << 20);
        assert!(store.cache_enabled());
        assert!(!ShardedPageStore::new(2).cache_enabled());
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        let mut buf = [0u8; 64];
        // first read misses and admits, second hits straight from cache
        store.read_block(1, 3, &mut buf).unwrap();
        assert_eq!(&buf[..], &img[3 * 64..4 * 64]);
        store.read_block(1, 3, &mut buf).unwrap();
        assert_eq!(&buf[..], &img[3 * 64..4 * 64]);
        let t = store.cache_totals();
        assert_eq!((t.hits, t.misses, t.admissions), (1, 1, 1));
        // a write to the resident block is absorbed: framing unchanged
        let bits_before = store.block_bits(1, 3).unwrap();
        let line = [0x5Au8; 64];
        let wr = store.write_block(1, 3, &line).unwrap();
        assert_eq!(wr.bits, bits_before);
        assert!(!wr.spilled);
        assert_eq!(store.block_bits(1, 3).unwrap(), bits_before, "recompression deferred");
        // reads see the deferred write, block- and page-granular
        let n = store.read_block(1, 3, &mut buf).unwrap();
        assert_eq!(&buf[..n], &line[..]);
        let mut expect = img.clone();
        expect[3 * 64..4 * 64].copy_from_slice(&line);
        assert_eq!(store.read(1).unwrap(), expect);
        assert_eq!(store.cache_totals().dirty_blocks, 1);
        // flushing brings the compressed tier up to date
        assert_eq!(store.flush_cache(), 1);
        assert_eq!(store.cache_totals().dirty_blocks, 0);
        assert_eq!(store.read(1).unwrap(), expect);
        assert_eq!(store.cache_totals().deferred_flushes, 1);
        // wrong-length writes error without corrupting the cache
        assert!(store.write_block(1, 3, &[0u8; 32]).is_err());
        let n = store.read_block(1, 3, &mut buf).unwrap();
        assert_eq!(&buf[..n], &line[..]);
        // a cold write goes through the frame, then admits the block
        store.write_block(1, 60, &line).unwrap();
        let n = store.read_block(1, 60, &mut buf).unwrap();
        assert_eq!(&buf[..n], &line[..]);
        // error surface matches the cacheless store
        assert!(store.read_block(1, 64, &mut buf).is_err());
        assert!(store.read_block(99, 0, &mut buf).is_err());
        assert!(store.write_block(99, 0, &line).is_err());
    }

    #[test]
    fn cached_accounting_and_remove_fold_deferred_writes() {
        let cfg = GbdiConfig::default();
        let img = vec![0u8; 4096];
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(1).with_cache(64 * 1024);
        store.publish_codec(Arc::clone(&codec));
        store.put(5, compress_page(&img, &codec));
        let mut buf = [0u8; 64];
        store.read_block(5, 0, &mut buf).unwrap(); // admit
        let line = [7u8; 64];
        store.write_block(5, 0, &line).unwrap(); // absorbed, now dirty
        // stored accounting charges the cache-resident bytes
        let (logical, stored) = store.usage();
        assert_eq!(logical, 4096);
        let frames = store.with_page(5, |p| p.stored_len()).unwrap();
        assert_eq!(stored, frames + 64);
        assert_eq!(store.stored_bytes(), stored);
        assert_eq!(store.cache_resident_bytes(), 64);
        let snaps = store.shard_metrics();
        assert_eq!(snaps[0].cached_blocks, 1);
        assert_eq!(snaps[0].cached_bytes, 64);
        assert_eq!(snaps[0].cached_dirty_blocks, 1);
        assert_eq!(snaps[0].cached_dirty_bytes, 64);
        assert_eq!(snaps[0].stored_bytes, stored as u64);
        // remove hands back the page with the deferred write folded in
        let page = store.remove(5).unwrap();
        assert_eq!(&page.frame.decompress().unwrap()[..64], &line[..]);
        assert_eq!(store.cache_resident_bytes(), 0);
        assert_eq!(store.cache_totals().deferred_flushes, 1);
        assert!(store.is_empty());
    }

    #[test]
    fn put_overwrite_invalidates_cached_blocks() {
        let cfg = GbdiConfig::default();
        let img_a = workloads::by_name("mcf").unwrap().generate(4096, 1);
        let img_b = workloads::by_name("svm").unwrap().generate(4096, 2);
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img_a, &cfg), cfg));
        let store = ShardedPageStore::new(2).with_cache(1 << 20);
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img_a, &codec));
        let mut buf = [0u8; 64];
        store.read_block(1, 0, &mut buf).unwrap();
        // write a deferred update, then overwrite the whole page: the
        // fresh image supersedes the cached (and dirty) blocks
        store.write_block(1, 0, &[9u8; 64]).unwrap();
        store.put(1, compress_page(&img_b, &codec));
        let n = store.read_block(1, 0, &mut buf).unwrap();
        assert_eq!(&buf[..n], &img_b[..64]);
        assert_eq!(store.read(1).unwrap(), img_b);
    }

    #[test]
    fn cached_store_stays_bounded_and_flushes_evictions() {
        // a cache far smaller than the write working set: every
        // deferred write must come back via an eviction flush, and the
        // final content must match a cacheless run
        let cfg = GbdiConfig::default();
        let img = vec![0u8; 4096];
        let codec: Arc<dyn BlockCodec> =
            Arc::new(GbdiCodec::new(analyze::analyze_image(&img, &cfg), cfg));
        let store = ShardedPageStore::new(1).with_cache(512); // 8 blocks
        store.publish_codec(Arc::clone(&codec));
        store.put(1, compress_page(&img, &codec));
        let mut rng = crate::util::prng::Rng::new(5);
        let mut noisy = [0u8; 64];
        let mut expect = img.clone();
        for round in 0..200 {
            let blk = (round * 7) % 64;
            if round % 3 == 2 {
                noisy[..].fill(0);
            } else {
                rng.fill_bytes(&mut noisy);
            }
            store.write_block(1, blk, &noisy).unwrap();
            expect[blk * 64..(blk + 1) * 64].copy_from_slice(&noisy);
        }
        assert_eq!(store.read(1).unwrap(), expect);
        let t = store.cache_totals();
        assert!(t.cached_bytes <= 512, "cache over budget: {} B", t.cached_bytes);
        assert!(t.evictions > 0, "a 8-block cache must evict under 200 writes");
        store.flush_cache();
        assert_eq!(store.read(1).unwrap(), expect, "content survives full flush");
        let stored = store.with_page(1, |p| p.stored_len()).unwrap();
        assert!(stored < 2 * (4096 + 4096 / 64 * 3 + 16), "stored {stored} B unbounded");
    }
}
