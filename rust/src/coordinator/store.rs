//! Versioned compressed-page store: pages encoded under different table
//! versions coexist; the table ring keeps every published version so any
//! page stays decodable until migrated.

use crate::gbdi::{decode, table::GlobalBaseTable, CompressedImage, GbdiConfig};
use crate::{Error, Result};
use std::collections::HashMap;

/// One stored page.
#[derive(Debug, Clone)]
pub struct StoredPage {
    /// Table version the payload references.
    pub table_version: u64,
    /// Original (logical) length.
    pub original_len: usize,
    /// Per-block bit lengths.
    pub block_bits: Vec<u32>,
    /// Packed payload.
    pub payload: Vec<u8>,
}

impl StoredPage {
    /// Compressed bytes (payload + framing approximation).
    pub fn stored_len(&self) -> usize {
        self.payload.len() + 2 * self.block_bits.len() + 16
    }
}

/// The page store + table ring.
#[derive(Debug, Default)]
pub struct PageStore {
    pages: HashMap<u64, StoredPage>,
    tables: HashMap<u64, GlobalBaseTable>,
}

impl PageStore {
    /// Empty store.
    pub fn new() -> Self {
        PageStore::default()
    }

    /// Publish a table version (idempotent; versions are immutable).
    pub fn publish_table(&mut self, table: GlobalBaseTable) {
        self.tables.entry(table.version).or_insert(table);
    }

    /// Look up a published table.
    pub fn table(&self, version: u64) -> Option<&GlobalBaseTable> {
        self.tables.get(&version)
    }

    /// Number of published table versions.
    pub fn table_count(&self) -> usize {
        self.tables.len()
    }

    /// Insert/overwrite a page.
    pub fn put(&mut self, page_id: u64, page: StoredPage) {
        debug_assert!(
            self.tables.contains_key(&page.table_version),
            "page references unpublished table v{}",
            page.table_version
        );
        self.pages.insert(page_id, page);
    }

    /// Get a stored page.
    pub fn get(&self, page_id: u64) -> Option<&StoredPage> {
        self.pages.get(&page_id)
    }

    /// Remove a page (returns it).
    pub fn remove(&mut self, page_id: u64) -> Option<StoredPage> {
        self.pages.remove(&page_id)
    }

    /// Number of stored pages.
    pub fn len(&self) -> usize {
        self.pages.len()
    }

    /// Whether the store is empty.
    pub fn is_empty(&self) -> bool {
        self.pages.is_empty()
    }

    /// Total compressed bytes stored.
    pub fn stored_bytes(&self) -> usize {
        self.pages.values().map(|p| p.stored_len()).sum()
    }

    /// Total logical bytes stored.
    pub fn logical_bytes(&self) -> usize {
        self.pages.values().map(|p| p.original_len).sum()
    }

    /// Ids of pages encoded with a version older than `version`.
    pub fn lagging_pages(&self, version: u64) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .pages
            .iter()
            .filter(|(_, p)| p.table_version < version)
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Decompress a page using its recorded table version.
    pub fn read(&self, page_id: u64, config: &GbdiConfig) -> Result<Vec<u8>> {
        let page = self
            .pages
            .get(&page_id)
            .ok_or_else(|| Error::Corrupt(format!("page {page_id} not found")))?;
        let table = self.tables.get(&page.table_version).ok_or_else(|| {
            Error::Corrupt(format!("table v{} not in ring", page.table_version))
        })?;
        let image = CompressedImage {
            table: table.clone(),
            original_len: page.original_len,
            block_bits: page.block_bits.clone(),
            payload: page.payload.clone(),
            chunk_blocks: 0,
            config: config.clone(),
        };
        decode::decompress_image(&image)
    }

    /// Drop table versions no page references anymore (except the newest
    /// `keep` versions). Returns how many were dropped.
    pub fn gc_tables(&mut self, keep: usize) -> usize {
        let referenced: std::collections::BTreeSet<u64> =
            self.pages.values().map(|p| p.table_version).collect();
        let mut versions: Vec<u64> = self.tables.keys().copied().collect();
        versions.sort_unstable();
        let keep_from = versions.len().saturating_sub(keep);
        let mut dropped = 0;
        for (i, v) in versions.into_iter().enumerate() {
            if i < keep_from && !referenced.contains(&v) {
                self.tables.remove(&v);
                dropped += 1;
            }
        }
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdi::{analyze, GbdiCodec};
    use crate::value::WordSize;
    use crate::workloads;

    fn compress_page(data: &[u8], table: &GlobalBaseTable, cfg: &GbdiConfig) -> StoredPage {
        let codec = GbdiCodec::new(table.clone(), cfg.clone());
        let comp = codec.compress_image(data);
        StoredPage {
            table_version: table.version,
            original_len: comp.original_len,
            block_bits: comp.block_bits,
            payload: comp.payload,
        }
    }

    #[test]
    fn pages_survive_table_swaps() {
        let cfg = GbdiConfig::default();
        let img_a = workloads::by_name("mcf").unwrap().generate(4096, 1);
        let img_b = workloads::by_name("svm").unwrap().generate(4096, 1);
        let mut t1 = analyze::analyze_image(&img_a, &cfg);
        t1.version = 1;
        let mut t2 = analyze::analyze_image(&img_b, &cfg);
        t2.version = 2;

        let mut store = PageStore::new();
        store.publish_table(t1.clone());
        store.put(10, compress_page(&img_a, &t1, &cfg));
        store.publish_table(t2.clone());
        store.put(20, compress_page(&img_b, &t2, &cfg));

        // both decode bit-exactly despite different table versions
        assert_eq!(store.read(10, &cfg).unwrap(), img_a);
        assert_eq!(store.read(20, &cfg).unwrap(), img_b);
        assert_eq!(store.lagging_pages(2), vec![10]);
        assert_eq!(store.lagging_pages(1), Vec::<u64>::new());
    }

    #[test]
    fn missing_page_and_table_error() {
        let cfg = GbdiConfig::default();
        let store = PageStore::new();
        assert!(store.read(99, &cfg).is_err());
    }

    #[test]
    fn gc_keeps_referenced_versions() {
        let cfg = GbdiConfig::default();
        let img = vec![7u8; 4096];
        let mut store = PageStore::new();
        for v in 1..=5 {
            let mut t = GlobalBaseTable::new(vec![(v * 1000, 8)], WordSize::W32, v);
            t.version = v;
            store.publish_table(t.clone());
            if v == 2 {
                store.put(1, compress_page(&img, &t, &cfg));
            }
        }
        let dropped = store.gc_tables(1);
        // v1, v3, v4 droppable; v2 referenced; v5 newest kept
        assert_eq!(dropped, 3);
        assert!(store.table(2).is_some());
        assert!(store.table(5).is_some());
        assert_eq!(store.read(1, &cfg).unwrap(), img);
    }

    #[test]
    fn accounting() {
        let cfg = GbdiConfig::default();
        let img = vec![0u8; 8192];
        let t = analyze::analyze_image(&img, &cfg);
        let mut store = PageStore::new();
        store.publish_table(t.clone());
        store.put(1, compress_page(&img, &t, &cfg));
        assert_eq!(store.len(), 1);
        assert_eq!(store.logical_bytes(), 8192);
        assert!(store.stored_bytes() < 2048, "zeros compress: {}", store.stored_bytes());
        store.remove(1).unwrap();
        assert!(store.is_empty());
    }
}
