//! Hot-block cache: a bounded S3-FIFO over uncompressed 64-byte blocks.
//!
//! One instance sits in front of each shard of the
//! [`ShardedPageStore`](super::store::ShardedPageStore) (behind the
//! shard's cache mutex). The structure itself is lock-free data — all
//! locking and all interaction with frames happens in the store, which
//! acquires the cache mutex *before* the shard's state lock, never the
//! reverse.
//!
//! The replacement policy is S3-FIFO (Yang et al., SOSP '23):
//!
//! * a **small** probationary FIFO (~10% of capacity) absorbs new
//!   admissions, so one-hit wonders wash out without disturbing the
//!   resident hot set;
//! * a **main** FIFO holds blocks that proved themselves — re-referenced
//!   in small (the ref bit), re-admitted while still in ghost, or
//!   admitted hot by the store's latency heuristic;
//! * a **ghost** FIFO remembers recently evicted keys (no data) so a
//!   quick second touch promotes straight to main.
//!
//! Entries carry a `dirty` bit: a deferred block write updates the
//! cached copy only, and the compressed frame is brought up to date when
//! the block is evicted, its page is removed/migrated, or the store
//! flushes explicitly. Eviction therefore *returns* the evicted blocks
//! — the store owns the flush, because flushing needs the shard lock.
//!
//! Queues use lazy deletion: each resident entry carries a sequence
//! number and its queue records `(key, seq)`, so promotions,
//! invalidations, and re-admissions never have to search a `VecDeque` —
//! stale queue slots are skipped when they surface at the head.

use std::collections::{HashMap, HashSet, VecDeque};

/// Cache key: `(page_id, block_index)`.
pub type BlockKey = (u64, u32);

/// A block pushed out of the cache by capacity pressure. `dirty` means
/// the data was never written back to the frame — the caller must flush
/// it through `Frame::write_block` or the write is lost.
#[derive(Debug)]
pub struct EvictedBlock {
    /// Page the block belongs to.
    pub page_id: u64,
    /// Block index within the page.
    pub block: u32,
    /// Whether the frame still holds a stale encoding of this block.
    pub dirty: bool,
    /// The uncompressed block bytes (moved out of the cache).
    pub data: Vec<u8>,
}

struct Entry {
    data: Vec<u8>,
    dirty: bool,
    referenced: bool,
    in_main: bool,
    seq: u64,
}

/// One shard's hot-block cache. Capacity is in *bytes* of cached block
/// data; queue/map overhead is not charged (it is a small constant per
/// 64-byte block).
pub struct BlockCache {
    capacity: usize,
    /// Byte budget for the probationary queue (~10% of capacity).
    small_target: usize,
    map: HashMap<BlockKey, Entry>,
    small: VecDeque<(BlockKey, u64)>,
    main: VecDeque<(BlockKey, u64)>,
    ghost: VecDeque<BlockKey>,
    ghost_set: HashSet<BlockKey>,
    ghost_cap: usize,
    used: usize,
    small_used: usize,
    dirty_blocks: usize,
    dirty_bytes: usize,
    seq: u64,
}

impl BlockCache {
    /// Empty cache bounded to `capacity_bytes` of block data.
    pub fn new(capacity_bytes: usize) -> Self {
        let capacity = capacity_bytes.max(64);
        BlockCache {
            capacity,
            small_target: (capacity / 10).max(64),
            map: HashMap::new(),
            small: VecDeque::new(),
            main: VecDeque::new(),
            ghost: VecDeque::new(),
            ghost_set: HashSet::new(),
            // remember about one capacity's worth of 64-byte evictees
            ghost_cap: (capacity / 64).max(16),
            used: 0,
            small_used: 0,
            dirty_blocks: 0,
            dirty_bytes: 0,
            seq: 0,
        }
    }

    /// Byte capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Resident blocks.
    pub fn resident_blocks(&self) -> usize {
        self.map.len()
    }

    /// Resident uncompressed bytes (clean + dirty).
    pub fn resident_bytes(&self) -> usize {
        self.used
    }

    /// Resident blocks whose frame encoding is stale.
    pub fn dirty_blocks(&self) -> usize {
        self.dirty_blocks
    }

    /// Bytes of dirty (deferred-write) block data.
    pub fn dirty_bytes(&self) -> usize {
        self.dirty_bytes
    }

    /// Serve a hit: returns the cached bytes and sets the ref bit, or
    /// `None` on a miss.
    pub fn get(&mut self, key: BlockKey) -> Option<&[u8]> {
        let e = self.map.get_mut(&key)?;
        e.referenced = true;
        Some(&e.data)
    }

    /// Length of the cached block without touching the ref bit (the
    /// write path validates the caller's buffer against it).
    pub fn cached_len(&self, key: BlockKey) -> Option<usize> {
        self.map.get(&key).map(|e| e.data.len())
    }

    /// Absorb a write into a resident block: overwrites the cached copy,
    /// marks it dirty + referenced, and leaves the frame untouched. The
    /// caller must have checked [`Self::cached_len`] first; `data` must
    /// match it exactly.
    pub fn absorb_write(&mut self, key: BlockKey, data: &[u8]) {
        let e = self.map.get_mut(&key).expect("absorb_write on a non-resident block");
        debug_assert_eq!(e.data.len(), data.len());
        e.data.copy_from_slice(data);
        e.referenced = true;
        if !e.dirty {
            e.dirty = true;
            self.dirty_blocks += 1;
            self.dirty_bytes += e.data.len();
        }
    }

    /// Admit a block. `hot` skips the probationary queue (the store sets
    /// it from its latency heuristic); a ghost hit does the same. Any
    /// blocks pushed out by capacity pressure are returned — dirty ones
    /// carry deferred writes the caller must flush.
    pub fn insert(
        &mut self,
        key: BlockKey,
        data: Vec<u8>,
        dirty: bool,
        hot: bool,
    ) -> Vec<EvictedBlock> {
        debug_assert!(!self.map.contains_key(&key), "insert over a resident block");
        if data.len() > self.capacity {
            // can never fit; hand it straight back
            return vec![EvictedBlock { page_id: key.0, block: key.1, dirty, data }];
        }
        let seq = self.seq;
        self.seq += 1;
        let len = data.len();
        let to_main = hot || self.ghost_set.contains(&key);
        self.map.insert(key, Entry { data, dirty, referenced: false, in_main: to_main, seq });
        self.used += len;
        if dirty {
            self.dirty_blocks += 1;
            self.dirty_bytes += len;
        }
        if to_main {
            self.main.push_back((key, seq));
        } else {
            self.small.push_back((key, seq));
            self.small_used += len;
        }
        let mut evicted = Vec::new();
        while self.used > self.capacity {
            let from_small = self.small_used > self.small_target || self.main.is_empty();
            let progressed = if from_small {
                self.evict_from_small(&mut evicted) || self.evict_from_main(&mut evicted)
            } else {
                self.evict_from_main(&mut evicted) || self.evict_from_small(&mut evicted)
            };
            if !progressed {
                debug_assert!(false, "cache over capacity with nothing evictable");
                break;
            }
        }
        evicted
    }

    /// Block indexes of this page with deferred writes, sorted.
    pub fn dirty_blocks_of_page(&self, page_id: u64) -> Vec<u32> {
        let mut blocks: Vec<u32> = self
            .map
            .iter()
            .filter(|((id, _), e)| *id == page_id && e.dirty)
            .map(|((_, b), _)| *b)
            .collect();
        blocks.sort_unstable();
        blocks
    }

    /// Page ids that have at least one deferred write, sorted.
    pub fn dirty_pages(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .map
            .iter()
            .filter(|(_, e)| e.dirty)
            .map(|((id, _), _)| *id)
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// The cached bytes of a resident block (no ref-bit side effect).
    pub fn data_of(&self, key: BlockKey) -> Option<&[u8]> {
        self.map.get(&key).map(|e| e.data.as_slice())
    }

    /// Mark a resident block clean after its deferred write was flushed.
    pub fn mark_clean(&mut self, key: BlockKey) {
        if let Some(e) = self.map.get_mut(&key) {
            if e.dirty {
                e.dirty = false;
                self.dirty_blocks -= 1;
                self.dirty_bytes -= e.data.len();
            }
        }
    }

    /// Drop every entry of a page (stale after a `put` overwrite, gone
    /// after a `remove`). Queue slots are left to lazy deletion. Returns
    /// how many entries were dropped. The caller is responsible for
    /// flushing dirty blocks *before* invalidating if the writes matter.
    pub fn invalidate_page(&mut self, page_id: u64) -> usize {
        let keys: Vec<BlockKey> =
            self.map.keys().filter(|(id, _)| *id == page_id).copied().collect();
        for key in &keys {
            let e = self.map.remove(key).expect("key collected from map");
            self.used -= e.data.len();
            if !e.in_main {
                self.small_used -= e.data.len();
            }
            if e.dirty {
                self.dirty_blocks -= 1;
                self.dirty_bytes -= e.data.len();
            }
        }
        keys.len()
    }

    /// One S3-FIFO step on the probationary queue: referenced survivors
    /// promote to main, the first unreferenced victim is evicted (and
    /// remembered in ghost). Returns whether a block was evicted.
    fn evict_from_small(&mut self, out: &mut Vec<EvictedBlock>) -> bool {
        while let Some((key, seq)) = self.small.pop_front() {
            let live = matches!(self.map.get(&key), Some(e) if e.seq == seq && !e.in_main);
            if !live {
                continue;
            }
            let e = self.map.get_mut(&key).expect("live entry");
            self.small_used -= e.data.len();
            if e.referenced {
                e.referenced = false;
                e.in_main = true;
                self.main.push_back((key, seq));
            } else {
                let e = self.map.remove(&key).expect("live entry");
                self.used -= e.data.len();
                if e.dirty {
                    self.dirty_blocks -= 1;
                    self.dirty_bytes -= e.data.len();
                }
                self.push_ghost(key);
                out.push(EvictedBlock {
                    page_id: key.0,
                    block: key.1,
                    dirty: e.dirty,
                    data: e.data,
                });
                return true;
            }
        }
        false
    }

    /// One S3-FIFO step on the main queue: referenced entries get a
    /// second lap (ref bit cleared), the first unreferenced victim is
    /// evicted. Returns whether a block was evicted.
    fn evict_from_main(&mut self, out: &mut Vec<EvictedBlock>) -> bool {
        while let Some((key, seq)) = self.main.pop_front() {
            let live = matches!(self.map.get(&key), Some(e) if e.seq == seq && e.in_main);
            if !live {
                continue;
            }
            let e = self.map.get_mut(&key).expect("live entry");
            if e.referenced {
                e.referenced = false;
                self.main.push_back((key, seq));
            } else {
                let e = self.map.remove(&key).expect("live entry");
                self.used -= e.data.len();
                if e.dirty {
                    self.dirty_blocks -= 1;
                    self.dirty_bytes -= e.data.len();
                }
                out.push(EvictedBlock {
                    page_id: key.0,
                    block: key.1,
                    dirty: e.dirty,
                    data: e.data,
                });
                return true;
            }
        }
        false
    }

    fn push_ghost(&mut self, key: BlockKey) {
        if self.ghost_set.insert(key) {
            self.ghost.push_back(key);
            while self.ghost.len() > self.ghost_cap {
                if let Some(old) = self.ghost.pop_front() {
                    self.ghost_set.remove(&old);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block(v: u8) -> Vec<u8> {
        vec![v; 64]
    }

    #[test]
    fn hits_and_misses() {
        let mut c = BlockCache::new(1024);
        assert!(c.get((1, 0)).is_none());
        assert!(c.insert((1, 0), block(7), false, false).is_empty());
        assert_eq!(c.get((1, 0)).unwrap(), &block(7)[..]);
        assert_eq!(c.resident_blocks(), 1);
        assert_eq!(c.resident_bytes(), 64);
        assert_eq!(c.dirty_blocks(), 0);
    }

    #[test]
    fn absorbed_writes_track_dirty_bytes() {
        let mut c = BlockCache::new(1024);
        c.insert((1, 0), block(1), false, false);
        assert_eq!(c.cached_len((1, 0)), Some(64));
        c.absorb_write((1, 0), &block(2));
        assert_eq!(c.dirty_blocks(), 1);
        assert_eq!(c.dirty_bytes(), 64);
        // a second absorb does not double-count
        c.absorb_write((1, 0), &block(3));
        assert_eq!(c.dirty_blocks(), 1);
        assert_eq!(c.get((1, 0)).unwrap(), &block(3)[..]);
        c.mark_clean((1, 0));
        assert_eq!(c.dirty_blocks(), 0);
        assert_eq!(c.dirty_bytes(), 0);
    }

    #[test]
    fn capacity_is_enforced_in_bytes() {
        // room for exactly 4 blocks
        let mut c = BlockCache::new(4 * 64);
        let mut evicted = Vec::new();
        for b in 0..8u32 {
            evicted.extend(c.insert((1, b), block(b as u8), false, false));
        }
        assert_eq!(c.resident_blocks(), 4);
        assert_eq!(c.resident_bytes(), 4 * 64);
        assert_eq!(evicted.len(), 4);
        for e in &evicted {
            assert!(!e.dirty);
        }
    }

    #[test]
    fn referenced_probationers_promote_instead_of_evicting() {
        let mut c = BlockCache::new(4 * 64);
        c.insert((1, 0), block(0), false, false);
        assert!(c.get((1, 0)).is_some()); // ref bit set
        for b in 1..8u32 {
            c.insert((1, b), block(b as u8), false, false);
        }
        // (1,0) survived the sweep that washed out the one-hit wonders
        assert!(c.data_of((1, 0)).is_some(), "re-referenced block must be promoted");
    }

    #[test]
    fn ghost_readmission_goes_to_main() {
        let mut c = BlockCache::new(4 * 64);
        // fill + overflow so (1,0) is evicted into ghost
        for b in 0..8u32 {
            c.insert((1, b), block(b as u8), false, false);
        }
        assert!(c.data_of((1, 0)).is_none());
        // re-admit: lands in main, so a later probationary sweep spares it
        c.insert((1, 0), block(0), false, false);
        for b in 100..104u32 {
            c.insert((1, b), block(0), false, false);
        }
        assert!(c.data_of((1, 0)).is_some(), "ghost hit must bypass probation");
    }

    #[test]
    fn dirty_evictions_hand_data_back() {
        let mut c = BlockCache::new(2 * 64);
        c.insert((9, 0), block(0xAA), true, false);
        assert_eq!(c.dirty_blocks(), 1);
        let mut evicted = Vec::new();
        for b in 1..4u32 {
            evicted.extend(c.insert((9, b), block(b as u8), false, false));
        }
        let dirty: Vec<&EvictedBlock> = evicted.iter().filter(|e| e.dirty).collect();
        assert_eq!(dirty.len(), 1);
        assert_eq!(dirty[0].page_id, 9);
        assert_eq!(dirty[0].block, 0);
        assert_eq!(dirty[0].data, block(0xAA));
        assert_eq!(c.dirty_blocks(), 0, "dirty bytes left with the eviction");
    }

    #[test]
    fn invalidate_page_drops_only_that_page() {
        let mut c = BlockCache::new(1024);
        c.insert((1, 0), block(1), true, false);
        c.insert((1, 1), block(2), false, false);
        c.insert((2, 0), block(3), true, false);
        assert_eq!(c.invalidate_page(1), 2);
        assert!(c.data_of((1, 0)).is_none());
        assert!(c.data_of((2, 0)).is_some());
        assert_eq!(c.resident_blocks(), 1);
        assert_eq!(c.resident_bytes(), 64);
        assert_eq!(c.dirty_blocks(), 1);
        // stale queue slots from page 1 must not break later evictions
        for b in 1..40u32 {
            c.insert((2, b), block(0), false, false);
        }
        assert!(c.resident_bytes() <= c.capacity());
    }

    #[test]
    fn dirty_page_enumeration_is_sorted_and_deduped() {
        let mut c = BlockCache::new(4096);
        c.insert((5, 3), block(0), true, false);
        c.insert((5, 1), block(0), true, false);
        c.insert((5, 2), block(0), false, false);
        c.insert((3, 0), block(0), true, false);
        assert_eq!(c.dirty_pages(), vec![3, 5]);
        assert_eq!(c.dirty_blocks_of_page(5), vec![1, 3]);
        assert_eq!(c.dirty_blocks_of_page(3), vec![0]);
        assert_eq!(c.dirty_blocks_of_page(99), Vec::<u32>::new());
    }

    #[test]
    fn reinsert_after_invalidate_is_consistent() {
        // a stale queue slot for a key must not shadow its fresh entry
        let mut c = BlockCache::new(8 * 64);
        c.insert((1, 0), block(1), false, false);
        c.invalidate_page(1);
        c.insert((1, 0), block(2), false, false);
        assert_eq!(c.data_of((1, 0)).unwrap(), &block(2)[..]);
        // churn until well past where the stale slot surfaces
        for b in 0..64u32 {
            c.insert((7, b), block(0), false, false);
        }
        assert!(c.resident_bytes() <= c.capacity());
        // internal byte accounting still reconciles with the map
        let total: usize = (0..64u32)
            .filter_map(|b| c.data_of((7, b)))
            .map(|d| d.len())
            .sum::<usize>()
            + c.data_of((1, 0)).map_or(0, |d| d.len());
        assert_eq!(total, c.resident_bytes());
    }

    #[test]
    fn oversized_block_bounces() {
        let mut c = BlockCache::new(64);
        let e = c.insert((1, 0), vec![0u8; 4096], true, true);
        assert_eq!(e.len(), 1);
        assert!(e[0].dirty);
        assert_eq!(c.resident_blocks(), 0);
    }
}
