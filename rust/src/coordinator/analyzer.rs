//! Background analyzer: turns sampled traffic into candidate global base
//! tables, scores them against the incumbent, and decides swaps.
//!
//! The clustering itself runs on any [`BaseSelector`] — full Lloyd
//! k-means, mini-batch with incumbent warm start, the histogram
//! selector, or the AOT JAX/Pallas artifact through PJRT
//! ([`crate::cluster::ArtifactSelector`]); the analyzer no longer
//! special-cases backends. The back half is always shared: centroids →
//! width-class fitting → [`GlobalBaseTable`]
//! ([`GlobalBaseTable::from_selection`]), and a candidate only replaces
//! the incumbent if it shrinks the estimated encoded size of the current
//! sample by at least `swap_margin`.
//!
//! On top of selection sits **drift detection**: once a table has been
//! adopted, the analyzer remembers how well it scored on the traffic it
//! was adopted for ([`Analyzer::note_adopted`]). While fresh samples
//! still score within `drift_margin` of that baseline, re-clustering is
//! skipped entirely ([`Analyzer::should_recluster`]) — scoring a
//! reservoir under the incumbent is one `O(n)` pass, so a stable
//! workload pays near-zero analysis cost and only a real phase change
//! triggers the selector.
//!
//! The analyzer's interaction with the sharded store is deliberately
//! minimal (DESIGN.md §8): a winning candidate is published with one
//! O(1) insert into the shared codec ring — never an O(shards) fan-out
//! or a store-wide stall — and the follow-up recompress migration
//! ([`super::service::CompressionService::recompress_step`]) walks one
//! shard at a time so maintenance only ever blocks the shard it is
//! currently migrating.

use crate::cluster::{BaseSelector, LloydSelector, Selection, SelectorConfig};
use crate::gbdi::table::GlobalBaseTable;
use crate::gbdi::GbdiConfig;
use crate::Result;

/// The analyzer: owns the selector and the scoring policy.
pub struct Analyzer {
    selector: Box<dyn BaseSelector>,
    config: GbdiConfig,
    sel_cfg: SelectorConfig,
    /// A candidate must beat the incumbent's estimated bits by this
    /// factor to be swapped in (hysteresis against churn).
    pub swap_margin: f64,
    /// Re-clustering is skipped while fresh samples score within this
    /// factor of the adopted table's baseline bits/word (drift
    /// detection); > 1.0, where 1.02 means "tolerate 2% degradation".
    pub drift_margin: f64,
    /// Bits/word the incumbent scored when it was adopted (None until a
    /// table has been adopted — a trivial initial table never blocks
    /// analysis).
    baseline_bits_per_word: Option<f64>,
}

impl Analyzer {
    /// New analyzer over `selector`. `config` supplies the base budget,
    /// width classes, and the selector knobs ([`SelectorConfig::from_gbdi`]).
    pub fn new(selector: Box<dyn BaseSelector>, config: GbdiConfig) -> Self {
        let sel_cfg = SelectorConfig::from_gbdi(&config);
        Analyzer {
            selector,
            config,
            sel_cfg,
            swap_margin: 0.98,
            drift_margin: 1.02,
            baseline_bits_per_word: None,
        }
    }

    /// Convenience: the reference configuration (full Lloyd k-means).
    pub fn native(config: GbdiConfig) -> Self {
        Analyzer::new(Box::new(LloydSelector), config)
    }

    /// The codec config this analyzer builds tables for.
    pub fn config(&self) -> &GbdiConfig {
        &self.config
    }

    /// Run one analysis over `samples` (word values), producing a table
    /// at `version`. Cold start — no incumbent is passed to the selector.
    pub fn analyze(&mut self, samples: &[u64], version: u64) -> Result<GlobalBaseTable> {
        self.analyze_warm(samples, None, version)
    }

    /// Run one analysis, letting incremental selectors warm-start from
    /// the incumbent table.
    pub fn analyze_warm(
        &mut self,
        samples: &[u64],
        incumbent: Option<&GlobalBaseTable>,
        version: u64,
    ) -> Result<GlobalBaseTable> {
        let selection: Selection = self.selector.select(samples, incumbent, &self.sel_cfg)?;
        Ok(GlobalBaseTable::from_selection(samples, &selection, &self.config, version))
    }

    /// Drift detection: does `incumbent` still score close enough to the
    /// traffic it was adopted for that re-clustering can be skipped?
    /// Always true until a table has been adopted ([`Self::note_adopted`]).
    pub fn should_recluster(&self, samples: &[u64], incumbent: &GlobalBaseTable) -> bool {
        if samples.is_empty() {
            return false;
        }
        match self.baseline_bits_per_word {
            None => true,
            Some(baseline) => {
                let current = self.estimate_bits(samples, incumbent) as f64 / samples.len() as f64;
                current > baseline * self.drift_margin
            }
        }
    }

    /// Record that `table` was adopted for traffic that looks like
    /// `samples` — the drift-detection baseline.
    pub fn note_adopted(&mut self, samples: &[u64], table: &GlobalBaseTable) {
        if !samples.is_empty() {
            self.baseline_bits_per_word =
                Some(self.estimate_bits(samples, table) as f64 / samples.len() as f64);
        }
    }

    /// Estimated encoded bits of `samples` under `table` (exact L3
    /// arithmetic; the artifact `sizeest` kernel computes the same number
    /// approximately on-TPU — used here when available as a cross-check).
    pub fn estimate_bits(&self, samples: &[u64], table: &GlobalBaseTable) -> u64 {
        let ptr_bits = self.config.base_ptr_bits() as u64;
        let word_bits = self.config.word_size.bits() as u64;
        samples
            .iter()
            .map(|&v| {
                ptr_bits
                    + match table.best_base(v) {
                        Some((_, _, w)) => w as u64,
                        None => word_bits,
                    }
            })
            .sum()
    }

    /// Decide whether `candidate` should replace `incumbent` for traffic
    /// that looks like `samples`.
    pub fn should_swap(
        &self,
        samples: &[u64],
        incumbent: &GlobalBaseTable,
        candidate: &GlobalBaseTable,
    ) -> bool {
        if samples.is_empty() {
            return false;
        }
        let old = self.estimate_bits(samples, incumbent);
        let new = self.estimate_bits(samples, candidate);
        (new as f64) < (old as f64) * self.swap_margin
    }

    /// Selector name (diagnostics).
    pub fn selector_name(&self) -> &'static str {
        self.selector.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{apply_delta, MiniBatchSelector, SelectorKind};
    use crate::util::prng::Rng;
    use crate::value::WordSize;

    fn mixture(seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        (0..4096)
            .map(|_| {
                let c = [50_000u64, 9_000_000, 3_000_000_000][rng.below(3) as usize];
                apply_delta(c, rng.range_i64(-100, 100), WordSize::W32)
            })
            .collect()
    }

    #[test]
    fn native_analysis_produces_good_table() {
        let cfg = GbdiConfig { num_bases: 16, ..Default::default() };
        let mut a = Analyzer::native(cfg);
        let samples = mixture(1);
        let table = a.analyze(&samples, 3).unwrap();
        assert_eq!(table.version, 3);
        // estimated bits should be far below raw (32 bits/word + ptr)
        let est = a.estimate_bits(&samples, &table);
        assert!(
            est < samples.len() as u64 * 20,
            "est {est} vs raw {}",
            samples.len() * 32
        );
    }

    #[test]
    fn every_selector_kind_analyzes_well() {
        let samples = mixture(4);
        for &kind in SelectorKind::all() {
            let cfg = GbdiConfig { num_bases: 16, ..Default::default() };
            let mut a = Analyzer::new(kind.build(), cfg);
            assert_eq!(a.selector_name(), kind.name());
            let table = a.analyze(&samples, 1).unwrap();
            let est = a.estimate_bits(&samples, &table);
            assert!(
                est < samples.len() as u64 * 24,
                "{}: est {est} vs raw {}",
                kind.name(),
                samples.len() * 32
            );
        }
    }

    #[test]
    fn swap_policy_prefers_better_tables() {
        let cfg = GbdiConfig { num_bases: 16, ..Default::default() };
        let mut a = Analyzer::native(cfg.clone());
        let samples = mixture(2);
        let good = a.analyze(&samples, 2).unwrap();
        let bad = GlobalBaseTable::new(vec![(123, 4)], cfg.word_size, 1);
        assert!(a.should_swap(&samples, &bad, &good));
        assert!(!a.should_swap(&samples, &good, &bad));
        // near-identical candidate loses to hysteresis
        let again = a.analyze(&samples, 3).unwrap();
        assert!(!a.should_swap(&samples, &good, &again));
        assert!(!a.should_swap(&[], &good, &again));
    }

    #[test]
    fn drift_detection_skips_stable_traffic_and_fires_on_phase_change() {
        let cfg = GbdiConfig { num_bases: 16, ..Default::default() };
        let mut a = Analyzer::new(Box::new(MiniBatchSelector), cfg);
        let phase_a = mixture(5);
        // before anything is adopted, analysis must always run
        let table = a.analyze(&phase_a, 1).unwrap();
        assert!(a.should_recluster(&phase_a, &table));
        a.note_adopted(&phase_a, &table);
        // same distribution, fresh sample: within the margin -> skip
        let phase_a2 = mixture(6);
        assert!(!a.should_recluster(&phase_a2, &table), "stable traffic must skip");
        // shifted distribution: outliers blow the budget -> recluster
        let mut rng = Rng::new(7);
        let phase_b: Vec<u64> =
            (0..4096).map(|_| apply_delta(1_700_000_000, rng.range_i64(-80, 80), WordSize::W32)).collect();
        assert!(a.should_recluster(&phase_b, &table), "phase change must recluster");
        // warm re-analysis adapts to the new phase
        let t2 = a.analyze_warm(&phase_b, Some(&table), 2).unwrap();
        assert!(a.should_swap(&phase_b, &table, &t2));
        // empty samples never trigger work
        assert!(!a.should_recluster(&[], &table));
    }

    #[test]
    fn empty_samples_yield_valid_table() {
        let cfg = GbdiConfig { num_bases: 8, ..Default::default() };
        let mut a = Analyzer::native(cfg);
        let t = a.analyze(&[], 1).unwrap();
        assert!(!t.is_empty());
    }
}
