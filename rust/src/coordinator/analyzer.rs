//! Background analyzer: turns sampled traffic into candidate global base
//! tables, scores them against the incumbent, and decides swaps.
//!
//! The clustering itself runs on one of two backends:
//!
//! * [`AnalyzerBackend::Artifact`] — the AOT-compiled JAX/Pallas k-means
//!   through PJRT ([`crate::runtime::ArtifactRuntime`]); the production
//!   configuration.
//! * [`AnalyzerBackend::Native`] — the pure-Rust `cluster::kmeans`
//!   (fallback when `artifacts/` is absent, and the ablation arm).
//!
//! Either way the back half is shared: centroids → width-class fitting →
//! [`GlobalBaseTable`] (see `gbdi::analyze::table_from_centroids`), and a
//! candidate only replaces the incumbent if it shrinks the estimated
//! encoded size of the current sample by at least `swap_margin`.

use crate::cluster::{kmeans, KmeansConfig, Metric};
use crate::gbdi::analyze::table_from_centroids;
use crate::gbdi::table::GlobalBaseTable;
use crate::gbdi::GbdiConfig;
use crate::runtime::{shape_samples, ArtifactRuntime, KMEANS_KS, N_SAMPLES};
use crate::util::prng::Rng;
use crate::Result;
use std::sync::Arc;

/// Which engine runs the clustering.
pub enum AnalyzerBackend {
    /// AOT JAX/Pallas artifact via PJRT.
    Artifact(Arc<ArtifactRuntime>),
    /// Pure-Rust k-means.
    Native,
}

impl AnalyzerBackend {
    /// Human-readable backend name (for logs/metrics).
    pub fn name(&self) -> &'static str {
        match self {
            AnalyzerBackend::Artifact(_) => "artifact(pjrt)",
            AnalyzerBackend::Native => "native(rust)",
        }
    }
}

/// The analyzer: owns the backend and the scoring policy.
pub struct Analyzer {
    backend: AnalyzerBackend,
    config: GbdiConfig,
    /// A candidate must beat the incumbent's estimated bits by this
    /// factor to be swapped in (hysteresis against churn).
    pub swap_margin: f64,
    rng: Rng,
}

impl Analyzer {
    /// New analyzer. `config.num_bases` selects the artifact K (rounded
    /// down to an available artifact when using the PJRT backend).
    pub fn new(backend: AnalyzerBackend, config: GbdiConfig) -> Self {
        let seed = config.seed;
        Analyzer { backend, config, swap_margin: 0.98, rng: Rng::new(seed) }
    }

    /// The codec config this analyzer builds tables for.
    pub fn config(&self) -> &GbdiConfig {
        &self.config
    }

    /// Seed `k` initial centroids from the sample (cheap k-means++-lite:
    /// random distinct picks plus the zero base's neighbourhood) — the
    /// contract the kmeans artifact expects.
    fn seed_init(&mut self, samples: &[u64], k: usize) -> Vec<f32> {
        let mut init = Vec::with_capacity(k);
        if samples.is_empty() {
            return vec![0.0; k];
        }
        for _ in 0..k {
            init.push(samples[self.rng.below(samples.len() as u64) as usize] as f32);
        }
        init
    }

    /// Run one analysis over `samples` (word values), producing a table
    /// at `version`.
    pub fn analyze(&mut self, samples: &[u64], version: u64) -> Result<GlobalBaseTable> {
        let k = self.config.num_bases.saturating_sub(1).max(1);
        // clone the Arc up front so the backend borrow does not pin `self`
        let artifact_rt = match &self.backend {
            AnalyzerBackend::Artifact(rt) => Some(Arc::clone(rt)),
            AnalyzerBackend::Native => None,
        };
        let centroids: Vec<u64> = match artifact_rt {
            Some(rt) => {
                // choose the largest available artifact K that fits
                let ak = *KMEANS_KS
                    .iter()
                    .filter(|&&a| a <= k.max(KMEANS_KS[0]))
                    .max()
                    .unwrap_or(&KMEANS_KS[0]);
                let x = shape_samples(samples);
                debug_assert_eq!(x.len(), N_SAMPLES);
                let init = self.seed_init(samples, ak);
                let fit = rt.kmeans(&x, &init)?;
                fit.centroids
                    .iter()
                    .zip(&fit.counts)
                    .filter(|&(_, &n)| n > 0.0)
                    .map(|(&c, _)| snap_word(c, &self.config))
                    .collect()
            }
            None => {
                let kcfg = KmeansConfig {
                    k,
                    iters: self.config.analysis_iters,
                    metric: Metric::BitCost,
                    width_classes: self.config.width_classes.clone(),
                    word_size: self.config.word_size,
                    seed: self.config.seed,
                };
                kmeans(samples, &kcfg).centroids
            }
        };
        let centroids = if centroids.is_empty() { vec![0] } else { centroids };
        Ok(table_from_centroids(samples, &centroids, &self.config, version))
    }

    /// Estimated encoded bits of `samples` under `table` (exact L3
    /// arithmetic; the artifact `sizeest` kernel computes the same number
    /// approximately on-TPU — used here when available as a cross-check).
    pub fn estimate_bits(&self, samples: &[u64], table: &GlobalBaseTable) -> u64 {
        let ptr_bits = self.config.base_ptr_bits() as u64;
        let word_bits = self.config.word_size.bits() as u64;
        samples
            .iter()
            .map(|&v| {
                ptr_bits
                    + match table.best_base(v) {
                        Some((_, _, w)) => w as u64,
                        None => word_bits,
                    }
            })
            .sum()
    }

    /// Decide whether `candidate` should replace `incumbent` for traffic
    /// that looks like `samples`.
    pub fn should_swap(
        &self,
        samples: &[u64],
        incumbent: &GlobalBaseTable,
        candidate: &GlobalBaseTable,
    ) -> bool {
        if samples.is_empty() {
            return false;
        }
        let old = self.estimate_bits(samples, incumbent);
        let new = self.estimate_bits(samples, candidate);
        (new as f64) < (old as f64) * self.swap_margin
    }

    /// Backend name (diagnostics).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

/// Snap an f32 centroid back to an exact word value (clamped to the word
/// range) — the precision hand-off from the f32 analysis plane to the
/// exact codec (DESIGN.md §5).
fn snap_word(c: f32, config: &GbdiConfig) -> u64 {
    let max = match config.word_size {
        crate::value::WordSize::W32 => u32::MAX as u64,
        crate::value::WordSize::W64 => u64::MAX,
    };
    let c = c as f64;
    if c <= 0.0 {
        0
    } else if c >= max as f64 {
        max
    } else {
        c.round() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::apply_delta;
    use crate::value::WordSize;

    fn mixture(seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        (0..4096)
            .map(|_| {
                let c = [50_000u64, 9_000_000, 3_000_000_000][rng.below(3) as usize];
                apply_delta(c, rng.range_i64(-100, 100), WordSize::W32)
            })
            .collect()
    }

    #[test]
    fn native_analysis_produces_good_table() {
        let cfg = GbdiConfig { num_bases: 16, ..Default::default() };
        let mut a = Analyzer::new(AnalyzerBackend::Native, cfg);
        let samples = mixture(1);
        let table = a.analyze(&samples, 3).unwrap();
        assert_eq!(table.version, 3);
        // estimated bits should be far below raw (32 bits/word + ptr)
        let est = a.estimate_bits(&samples, &table);
        assert!(
            est < samples.len() as u64 * 20,
            "est {est} vs raw {}",
            samples.len() * 32
        );
    }

    #[test]
    fn swap_policy_prefers_better_tables() {
        let cfg = GbdiConfig { num_bases: 16, ..Default::default() };
        let mut a = Analyzer::new(AnalyzerBackend::Native, cfg.clone());
        let samples = mixture(2);
        let good = a.analyze(&samples, 2).unwrap();
        let bad = GlobalBaseTable::new(vec![(123, 4)], cfg.word_size, 1);
        assert!(a.should_swap(&samples, &bad, &good));
        assert!(!a.should_swap(&samples, &good, &bad));
        // near-identical candidate loses to hysteresis
        let again = a.analyze(&samples, 3).unwrap();
        assert!(!a.should_swap(&samples, &good, &again));
        assert!(!a.should_swap(&[], &good, &again));
    }

    #[test]
    fn snap_word_clamps() {
        let cfg = GbdiConfig::default();
        assert_eq!(snap_word(-5.0, &cfg), 0);
        assert_eq!(snap_word(5e12, &cfg), u32::MAX as u64);
        assert_eq!(snap_word(1000.4, &cfg), 1000);
    }

    #[test]
    fn empty_samples_yield_valid_table() {
        let cfg = GbdiConfig { num_bases: 8, ..Default::default() };
        let mut a = Analyzer::new(AnalyzerBackend::Native, cfg);
        let t = a.analyze(&[], 1).unwrap();
        assert!(!t.is_empty());
    }
}
