//! The L3 coordination plane: a compression *service* shaped like the
//! memory-controller firmware the paper's system implies — pages stream
//! in, workers compress them against the current global base table, and
//! a background analyzer continuously re-derives the table from sampled
//! traffic (running the AOT-compiled JAX/Pallas k-means through
//! [`crate::runtime`] when artifacts are present, or the native Rust
//! fallback otherwise).
//!
//! Key invariants:
//!
//! * **Python never runs here.** The analyzer executes pre-compiled HLO.
//! * **Table versioning.** Every stored page records the table version
//!   that encoded it; the [`store::PageStore`] keeps all published
//!   versions so any page decompresses bit-exactly at any time.
//! * **Analysis off the hot path.** Workers only read the current codec
//!   (an `Arc` swap); clustering happens on the analyzer thread.

pub mod analyzer;
pub mod metrics;
pub mod service;
pub mod store;

pub use analyzer::{Analyzer, AnalyzerBackend};
pub use metrics::{Metrics, MetricsSnapshot};
pub use service::{CompressionService, ServiceConfig};
pub use store::PageStore;
