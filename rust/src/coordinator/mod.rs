//! The L3 coordination plane: a compression *service* shaped like the
//! memory-controller firmware the paper's system implies — pages stream
//! in, workers compress them against the current global base table, and
//! a background analyzer continuously re-derives the table from sampled
//! traffic (running the AOT-compiled JAX/Pallas k-means through
//! [`crate::runtime`] when artifacts are present, or the native Rust
//! fallback otherwise).
//!
//! Key invariants:
//!
//! * **Python never runs here.** The analyzer executes pre-compiled HLO.
//! * **Codec versioning.** Every stored page records the codec version
//!   that encoded it; the [`store::PageStore`] keeps all published
//!   versions (as `Arc<dyn BlockCodec>`) so any page decompresses
//!   bit-exactly at any time.
//! * **One codec seam.** The service is generic over
//!   [`crate::codec::BlockCodec`]: the adaptive path swaps GBDI table
//!   versions; [`service::CompressionService::start_static`] serves any
//!   baseline (BDI, FPC) through the identical pipeline.
//! * **Analysis off the hot path.** Workers only read the current codec
//!   (an `Arc` swap); clustering happens on the analyzer thread.

pub mod analyzer;
pub mod metrics;
pub mod service;
pub mod store;

pub use analyzer::{Analyzer, AnalyzerBackend};
pub use metrics::{Metrics, MetricsSnapshot};
pub use service::{CompressionService, ServiceConfig};
pub use store::PageStore;
