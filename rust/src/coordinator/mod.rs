//! The L3 coordination plane: a compression *service* shaped like the
//! memory-controller firmware the paper's system implies — pages stream
//! in, workers compress them against the current global base table, and
//! a background analyzer continuously re-derives the table from sampled
//! traffic through the pluggable [`crate::cluster::BaseSelector`] engine
//! (full Lloyd k-means, mini-batch with incumbent warm start, the
//! histogram selector, or the AOT-compiled JAX/Pallas k-means through
//! [`crate::runtime`]).
//!
//! Key invariants:
//!
//! * **Python never runs here.** The artifact selector executes
//!   pre-compiled HLO.
//! * **Analysis is incremental by default.** Drift detection scores the
//!   reservoir under the incumbent table and skips re-clustering while
//!   the score stays within `drift_margin` of the adoption baseline;
//!   warm-start selectors reuse the incumbent's centroids when they do
//!   run.
//! * **Codec versioning.** Every stored page records the codec version
//!   that encoded it; the page store keeps all published versions (as
//!   `Arc<dyn BlockCodec>`) so any page decompresses bit-exactly at any
//!   time.
//! * **One codec seam.** The service is generic over
//!   [`crate::codec::BlockCodec`]: the adaptive path swaps GBDI table
//!   versions; [`service::CompressionService::start_static`] serves any
//!   baseline (BDI, FPC) through the identical pipeline.
//! * **Analysis off the hot path.** Workers only read the current codec
//!   (an `Arc` swap); clustering happens on the analyzer thread.
//! * **The store is sharded.** The service serves from a
//!   [`store::ShardedPageStore`]: N independently locked shards routed
//!   by a page-id hash, so block GETs/PUTs on different shards never
//!   contend, ingest batches take each shard lock once per batch
//!   ([`service::CompressionService::submit_batch`]), and recompression
//!   migration walks one shard at a time — maintenance never stalls
//!   foreground traffic on other shards (DESIGN.md §8). The single-lock
//!   [`store::PageStore`] remains as the reference semantics the
//!   equivalence property tests check the sharded store against.
//! * **Hot blocks stay uncompressed.** An optional per-shard S3-FIFO
//!   [`cache::BlockCache`] serves the Zipfian hot set straight from
//!   uncompressed memory and *defers* recompression of write-hot
//!   blocks until they cool (eviction, page removal/migration, or an
//!   explicit flush) — off by default, observationally equivalent when
//!   on, and honestly charged in the storage accounting.
//! * **Durability is optional and sits below.** With a
//!   [`crate::persist::Durability`] engine attached
//!   ([`service::ServiceConfig::persist`]), every accepted mutation is
//!   WAL-logged before it is applied and the store is periodically
//!   checkpointed; the service adopts the recovered store on start and
//!   folds a final checkpoint on shutdown. Without one (the default)
//!   none of that code runs (DESIGN.md §12). Shard count is elastic
//!   either way: [`store::ShardedPageStore::resize_shards`] retopologizes
//!   online while concurrent GETs/PUTs queue behind one lock.
//! * **Corruption is detected, fenced, and healed.** An optional
//!   integrity plane ([`store::ShardedPageStore::with_integrity`],
//!   DESIGN.md §13) keeps an incrementally maintained CRC-32 digest per
//!   page; a budgeted background scrubber re-verifies them, failed
//!   pages are quarantined (every read answers
//!   [`crate::Error::DataLoss`], never possibly-wrong bytes) and healed
//!   from durable state when persistence is on. Off by default — the
//!   side maps stay empty and no path changes.

pub mod analyzer;
pub mod cache;
pub mod metrics;
pub mod service;
pub mod store;

pub use analyzer::Analyzer;
pub use cache::{BlockCache, EvictedBlock};
pub use metrics::{
    CacheGauges, CacheTotals, IntegrityTotals, Metrics, MetricsSnapshot, ShardMetrics,
    ShardMetricsSnapshot,
};
pub use service::{CompressionService, ServiceConfig};
pub use store::{IntegrityConfig, PageStore, ScrubOutcome, ShardedPageStore, StoredPage};
