//! Lock-free metrics registry for the coordinator (atomics only — the
//! hot path must never take a lock to count).
//!
//! Two granularities:
//!
//! * [`Metrics`] / [`MetricsSnapshot`] — service-wide totals (pages,
//!   bytes, analyses, block-op counts and latencies).
//! * [`ShardMetrics`] / [`ShardMetricsSnapshot`] — per-shard counters
//!   owned by each shard of the
//!   [`ShardedPageStore`](super::store::ShardedPageStore): occupancy,
//!   exclusive lock-hold time, block read/write latency, the
//!   hot-block cache tier (hits, misses, admissions, evictions,
//!   deferred flushes, plus residency gauges), and the integrity plane
//!   (pages scrubbed, corruptions detected, pages healed/quarantined).
//!   The invariant the stress tests pin down: per-shard block-op
//!   counters sum exactly to the service-wide totals, because both
//!   sides count the same successful operations once. Service-wide
//!   cache and integrity totals are the sum of the shard snapshots
//!   ([`CacheTotals::from_shards`], [`IntegrityTotals::from_shards`]) —
//!   there is no second counter to drift.

use std::sync::atomic::{AtomicU64, Ordering};

/// Service-wide counters. All methods are `&self` and wait-free.
#[derive(Debug, Default)]
pub struct Metrics {
    pages_in: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    compress_ns: AtomicU64,
    analyses: AtomicU64,
    analyses_skipped: AtomicU64,
    table_swaps: AtomicU64,
    table_rejects: AtomicU64,
    recompressions: AtomicU64,
    read_errors: AtomicU64,
    write_errors: AtomicU64,
    block_reads: AtomicU64,
    block_read_ns: AtomicU64,
    block_writes: AtomicU64,
    block_write_ns: AtomicU64,
}

/// Point-in-time copy of [`Metrics`].
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Pages compressed.
    pub pages_in: u64,
    /// Logical bytes ingested.
    pub bytes_in: u64,
    /// Compressed bytes produced.
    pub bytes_out: u64,
    /// Nanoseconds spent compressing (across workers).
    pub compress_ns: u64,
    /// Background analyses completed.
    pub analyses: u64,
    /// Analysis rounds skipped by drift detection (incumbent still good).
    pub analyses_skipped: u64,
    /// Analyses that published a new table version.
    pub table_swaps: u64,
    /// Analyses whose candidate lost to the incumbent table.
    pub table_rejects: u64,
    /// Pages migrated to a newer table version.
    pub recompressions: u64,
    /// Failed page/block reads.
    pub read_errors: u64,
    /// Failed block writes.
    pub write_errors: u64,
    /// Single-block GETs served straight from frames.
    pub block_reads: u64,
    /// Nanoseconds spent serving block reads.
    pub block_read_ns: u64,
    /// Single-block PUTs (in-place recompression) served.
    pub block_writes: u64,
    /// Nanoseconds spent serving block writes.
    pub block_write_ns: u64,
}

impl MetricsSnapshot {
    /// Aggregate compression ratio so far (1.0 when nothing ingested).
    pub fn ratio(&self) -> f64 {
        if self.bytes_out == 0 {
            1.0
        } else {
            self.bytes_in as f64 / self.bytes_out as f64
        }
    }

    /// Compression throughput in MiB/s (0 when nothing measured).
    pub fn compress_mib_s(&self) -> f64 {
        if self.compress_ns == 0 {
            0.0
        } else {
            self.bytes_in as f64 / (1024.0 * 1024.0) / (self.compress_ns as f64 / 1e9)
        }
    }

    /// Mean single-block read latency in nanoseconds (0 before the
    /// first block GET).
    pub fn block_read_mean_ns(&self) -> f64 {
        if self.block_reads == 0 {
            0.0
        } else {
            self.block_read_ns as f64 / self.block_reads as f64
        }
    }

    /// Mean single-block write latency in nanoseconds (0 before the
    /// first block PUT).
    pub fn block_write_mean_ns(&self) -> f64 {
        if self.block_writes == 0 {
            0.0
        } else {
            self.block_write_ns as f64 / self.block_writes as f64
        }
    }
}

impl Metrics {
    /// Fresh zeroed registry.
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record one compressed page.
    pub fn page(&self, bytes_in: u64, bytes_out: u64, ns: u64) {
        self.pages_in.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(bytes_in, Ordering::Relaxed);
        self.bytes_out.fetch_add(bytes_out, Ordering::Relaxed);
        self.compress_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record an analysis round skipped by drift detection.
    pub fn analysis_skipped(&self) {
        self.analyses_skipped.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an analysis round; `swapped` = published a new table.
    pub fn analysis(&self, swapped: bool) {
        self.analyses.fetch_add(1, Ordering::Relaxed);
        if swapped {
            self.table_swaps.fetch_add(1, Ordering::Relaxed);
        } else {
            self.table_rejects.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a page migration.
    pub fn recompression(&self) {
        self.recompressed(1);
    }

    /// Record a batch of `n` page migrations in one atomic add (the
    /// per-shard migration walk reports whole shards at a time).
    pub fn recompressed(&self, n: u64) {
        self.recompressions.fetch_add(n, Ordering::Relaxed);
    }

    /// Record a failed read.
    pub fn read_error(&self) {
        self.read_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a failed block write.
    pub fn write_error(&self) {
        self.write_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one served single-block read and its latency.
    pub fn block_read(&self, ns: u64) {
        self.block_reads.fetch_add(1, Ordering::Relaxed);
        self.block_read_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one served single-block write and its latency.
    pub fn block_write(&self, ns: u64) {
        self.block_writes.fetch_add(1, Ordering::Relaxed);
        self.block_write_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Snapshot all counters.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            pages_in: self.pages_in.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            compress_ns: self.compress_ns.load(Ordering::Relaxed),
            analyses: self.analyses.load(Ordering::Relaxed),
            analyses_skipped: self.analyses_skipped.load(Ordering::Relaxed),
            table_swaps: self.table_swaps.load(Ordering::Relaxed),
            table_rejects: self.table_rejects.load(Ordering::Relaxed),
            recompressions: self.recompressions.load(Ordering::Relaxed),
            read_errors: self.read_errors.load(Ordering::Relaxed),
            write_errors: self.write_errors.load(Ordering::Relaxed),
            block_reads: self.block_reads.load(Ordering::Relaxed),
            block_read_ns: self.block_read_ns.load(Ordering::Relaxed),
            block_writes: self.block_writes.load(Ordering::Relaxed),
            block_write_ns: self.block_write_ns.load(Ordering::Relaxed),
        }
    }
}

/// Per-shard hot-path counters, owned by one shard of the
/// [`ShardedPageStore`](super::store::ShardedPageStore). All methods are
/// `&self` and wait-free; occupancy gauges (pages, bytes) are read from
/// the shard's page map at snapshot time rather than counted here, so
/// they can never drift from the map itself.
#[derive(Debug, Default)]
pub struct ShardMetrics {
    block_reads: AtomicU64,
    block_read_ns: AtomicU64,
    block_writes: AtomicU64,
    block_write_ns: AtomicU64,
    lock_holds: AtomicU64,
    lock_hold_ns: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_admissions: AtomicU64,
    cache_evictions: AtomicU64,
    deferred_flushes: AtomicU64,
    scrubbed: AtomicU64,
    corrupt_detected: AtomicU64,
    healed: AtomicU64,
    quarantined: AtomicU64,
}

impl ShardMetrics {
    /// Fresh zeroed registry.
    pub fn new() -> Self {
        ShardMetrics::default()
    }

    /// Record one served single-block read and its latency (includes the
    /// shard-lock wait, so contention shows up here).
    pub fn block_read(&self, ns: u64) {
        self.block_reads.fetch_add(1, Ordering::Relaxed);
        self.block_read_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one served single-block write and its latency.
    pub fn block_write(&self, ns: u64) {
        self.block_writes.fetch_add(1, Ordering::Relaxed);
        self.block_write_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one exclusive (write-side) lock acquisition and how long
    /// the guard was held — the quantity shard sizing tunes against.
    pub fn lock_hold(&self, ns: u64) {
        self.lock_holds.fetch_add(1, Ordering::Relaxed);
        self.lock_hold_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record a block op served straight from the hot-block cache (a
    /// read hit, or a write absorbed into a resident dirty block).
    pub fn cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a block op that had to go through the compressed frame.
    pub fn cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a block admitted into the cache after a miss.
    pub fn cache_admission(&self) {
        self.cache_admissions.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` blocks pushed out of the cache by capacity pressure.
    pub fn cache_evicted(&self, n: u64) {
        self.cache_evictions.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` deferred block writes flushed back through their
    /// frames (on eviction, page removal/migration, or explicit flush).
    pub fn deferred_flushed(&self, n: u64) {
        self.deferred_flushes.fetch_add(n, Ordering::Relaxed);
    }

    /// Record one page whose image digest was re-verified (by the
    /// background scrubber or an explicit scrub call).
    pub fn scrubbed(&self) {
        self.scrubbed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one confirmed digest mismatch (scrub or verified read).
    pub fn corrupt_detected(&self) {
        self.corrupt_detected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one quarantined page replaced with a verified copy
    /// recovered from durable state.
    pub fn healed(&self) {
        self.healed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one page entering quarantine (fenced from serving).
    pub fn quarantined(&self) {
        self.quarantined.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold another registry's counters into this one — an online shard
    /// resize retires shard indices and must not lose their history, or
    /// per-shard sums would stop matching the service-wide totals.
    pub fn absorb(&self, other: &ShardMetrics) {
        macro_rules! fold {
            ($($field:ident),*) => {
                $(self.$field.fetch_add(other.$field.load(Ordering::Relaxed), Ordering::Relaxed);)*
            };
        }
        fold!(
            block_reads,
            block_read_ns,
            block_writes,
            block_write_ns,
            lock_holds,
            lock_hold_ns,
            cache_hits,
            cache_misses,
            cache_admissions,
            cache_evictions,
            deferred_flushes,
            scrubbed,
            corrupt_detected,
            healed,
            quarantined
        );
    }

    /// Live mean block-read latency in nanoseconds (0 before the first
    /// read) — the cache admission heuristic compares each miss's
    /// decode cost against it without taking a snapshot.
    pub fn block_read_mean_ns(&self) -> f64 {
        let n = self.block_reads.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.block_read_ns.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Snapshot the counters, attaching the occupancy gauges the caller
    /// read under the shard lock (and cache mutex).
    pub fn snapshot(
        &self,
        shard: usize,
        pages: u64,
        logical_bytes: u64,
        stored_bytes: u64,
        cache: CacheGauges,
    ) -> ShardMetricsSnapshot {
        ShardMetricsSnapshot {
            shard,
            pages,
            logical_bytes,
            stored_bytes,
            block_reads: self.block_reads.load(Ordering::Relaxed),
            block_read_ns: self.block_read_ns.load(Ordering::Relaxed),
            block_writes: self.block_writes.load(Ordering::Relaxed),
            block_write_ns: self.block_write_ns.load(Ordering::Relaxed),
            lock_holds: self.lock_holds.load(Ordering::Relaxed),
            lock_hold_ns: self.lock_hold_ns.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_admissions: self.cache_admissions.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            deferred_flushes: self.deferred_flushes.load(Ordering::Relaxed),
            scrubbed: self.scrubbed.load(Ordering::Relaxed),
            corrupt_detected: self.corrupt_detected.load(Ordering::Relaxed),
            healed: self.healed.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            cached_blocks: cache.blocks,
            cached_bytes: cache.bytes,
            cached_dirty_blocks: cache.dirty_blocks,
            cached_dirty_bytes: cache.dirty_bytes,
        }
    }
}

/// Occupancy gauges of one shard's hot-block cache, read under the
/// cache mutex at snapshot time (all zero when the cache is off).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheGauges {
    /// Blocks resident in the cache.
    pub blocks: u64,
    /// Uncompressed bytes resident in the cache.
    pub bytes: u64,
    /// Resident blocks carrying a deferred (unflushed) write.
    pub dirty_blocks: u64,
    /// Bytes of deferred-write block data.
    pub dirty_bytes: u64,
}

/// Point-in-time copy of one shard's [`ShardMetrics`] plus its occupancy.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardMetricsSnapshot {
    /// Shard index (0-based).
    pub shard: usize,
    /// Pages resident in this shard.
    pub pages: u64,
    /// Logical bytes resident in this shard.
    pub logical_bytes: u64,
    /// Compressed bytes resident in this shard.
    pub stored_bytes: u64,
    /// Single-block reads served by this shard.
    pub block_reads: u64,
    /// Nanoseconds spent serving this shard's block reads.
    pub block_read_ns: u64,
    /// Single-block writes served by this shard.
    pub block_writes: u64,
    /// Nanoseconds spent serving this shard's block writes.
    pub block_write_ns: u64,
    /// Exclusive lock acquisitions on this shard.
    pub lock_holds: u64,
    /// Nanoseconds the exclusive lock was held in total.
    pub lock_hold_ns: u64,
    /// Block ops served straight from the hot-block cache.
    pub cache_hits: u64,
    /// Block ops that went through the compressed frame.
    pub cache_misses: u64,
    /// Blocks admitted into the cache.
    pub cache_admissions: u64,
    /// Blocks evicted from the cache by capacity pressure.
    pub cache_evictions: u64,
    /// Deferred block writes flushed back through frames.
    pub deferred_flushes: u64,
    /// Pages whose image digest was re-verified.
    pub scrubbed: u64,
    /// Confirmed digest mismatches (scrub or verified read).
    pub corrupt_detected: u64,
    /// Quarantined pages replaced with a verified durable copy.
    pub healed: u64,
    /// Pages that entered quarantine.
    pub quarantined: u64,
    /// Blocks resident in the cache at snapshot time.
    pub cached_blocks: u64,
    /// Uncompressed bytes resident in the cache at snapshot time.
    pub cached_bytes: u64,
    /// Resident blocks with a deferred write at snapshot time.
    pub cached_dirty_blocks: u64,
    /// Bytes of deferred-write data at snapshot time.
    pub cached_dirty_bytes: u64,
}

impl ShardMetricsSnapshot {
    /// Mean block-read latency in nanoseconds (0 before the first read).
    pub fn block_read_mean_ns(&self) -> f64 {
        if self.block_reads == 0 {
            0.0
        } else {
            self.block_read_ns as f64 / self.block_reads as f64
        }
    }

    /// Mean block-write latency in nanoseconds (0 before the first
    /// write).
    pub fn block_write_mean_ns(&self) -> f64 {
        if self.block_writes == 0 {
            0.0
        } else {
            self.block_write_ns as f64 / self.block_writes as f64
        }
    }

    /// Mean exclusive lock-hold time in nanoseconds (0 before the first
    /// exclusive acquisition).
    pub fn lock_hold_mean_ns(&self) -> f64 {
        if self.lock_holds == 0 {
            0.0
        } else {
            self.lock_hold_ns as f64 / self.lock_holds as f64
        }
    }

    /// Fraction of block ops served from the cache (0 before the first
    /// op or with the cache off).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

/// Service-wide hot-block cache totals: the sum of the per-shard
/// snapshots, so the totals can never drift from the shard counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheTotals {
    /// Block ops served straight from the cache.
    pub hits: u64,
    /// Block ops that went through the compressed frames.
    pub misses: u64,
    /// Blocks admitted into the cache.
    pub admissions: u64,
    /// Blocks evicted by capacity pressure.
    pub evictions: u64,
    /// Deferred block writes flushed back through frames.
    pub deferred_flushes: u64,
    /// Blocks resident across all shard caches.
    pub cached_blocks: u64,
    /// Uncompressed bytes resident across all shard caches.
    pub cached_bytes: u64,
    /// Resident blocks with a deferred write.
    pub dirty_blocks: u64,
    /// Bytes of deferred-write data.
    pub dirty_bytes: u64,
}

impl CacheTotals {
    /// Sum the per-shard snapshots into service totals.
    pub fn from_shards(shards: &[ShardMetricsSnapshot]) -> Self {
        let mut t = CacheTotals::default();
        for s in shards {
            t.hits += s.cache_hits;
            t.misses += s.cache_misses;
            t.admissions += s.cache_admissions;
            t.evictions += s.cache_evictions;
            t.deferred_flushes += s.deferred_flushes;
            t.cached_blocks += s.cached_blocks;
            t.cached_bytes += s.cached_bytes;
            t.dirty_blocks += s.cached_dirty_blocks;
            t.dirty_bytes += s.cached_dirty_bytes;
        }
        t
    }

    /// Fraction of block ops served from the cache (0 before the first
    /// op or with the cache off).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Service-wide integrity-plane totals: the sum of the per-shard
/// snapshots, same no-second-counter rule as [`CacheTotals`]. All four
/// are monotonic event counters (quarantine *entries*, not residency),
/// so the network STATS vector can export them append-only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct IntegrityTotals {
    /// Pages whose image digest was re-verified.
    pub scrubbed: u64,
    /// Confirmed digest mismatches (scrub or verified read).
    pub corrupt_detected: u64,
    /// Quarantined pages replaced with a verified durable copy.
    pub healed: u64,
    /// Pages that entered quarantine.
    pub quarantined: u64,
}

impl IntegrityTotals {
    /// Sum the per-shard snapshots into service totals.
    pub fn from_shards(shards: &[ShardMetricsSnapshot]) -> Self {
        let mut t = IntegrityTotals::default();
        for s in shards {
            t.scrubbed += s.scrubbed;
            t.corrupt_detected += s.corrupt_detected;
            t.healed += s.healed;
            t.quarantined += s.quarantined;
        }
        t
    }
}

#[cfg(test)]
mod shard_tests {
    use super::*;

    #[test]
    fn shard_counters_accumulate() {
        let m = ShardMetrics::new();
        m.block_read(100);
        m.block_read(300);
        m.block_write(500);
        m.lock_hold(40);
        m.lock_hold(60);
        assert_eq!(m.block_read_mean_ns(), 200.0);
        let s = m.snapshot(3, 7, 7 * 4096, 9000, CacheGauges::default());
        assert_eq!(s.shard, 3);
        assert_eq!(s.pages, 7);
        assert_eq!(s.logical_bytes, 7 * 4096);
        assert_eq!(s.stored_bytes, 9000);
        assert_eq!(s.block_reads, 2);
        assert_eq!(s.block_read_mean_ns(), 200.0);
        assert_eq!(s.block_writes, 1);
        assert_eq!(s.block_write_mean_ns(), 500.0);
        assert_eq!(s.lock_holds, 2);
        assert_eq!(s.lock_hold_mean_ns(), 50.0);
        assert_eq!(s.cache_hit_rate(), 0.0);
    }

    #[test]
    fn empty_shard_snapshot_sane() {
        let m = ShardMetrics::new();
        assert_eq!(m.block_read_mean_ns(), 0.0);
        let s = m.snapshot(0, 0, 0, 0, CacheGauges::default());
        assert_eq!(s.block_read_mean_ns(), 0.0);
        assert_eq!(s.block_write_mean_ns(), 0.0);
        assert_eq!(s.lock_hold_mean_ns(), 0.0);
        assert_eq!(s.cache_hit_rate(), 0.0);
    }

    #[test]
    fn cache_counters_accumulate_and_sum() {
        let a = ShardMetrics::new();
        a.cache_hit();
        a.cache_hit();
        a.cache_miss();
        a.cache_admission();
        a.cache_evicted(3);
        a.deferred_flushed(2);
        let b = ShardMetrics::new();
        b.cache_hit();
        b.cache_miss();
        let ga = CacheGauges { blocks: 4, bytes: 256, dirty_blocks: 1, dirty_bytes: 64 };
        let gb = CacheGauges { blocks: 2, bytes: 128, dirty_blocks: 0, dirty_bytes: 0 };
        let snaps = vec![a.snapshot(0, 0, 0, 0, ga), b.snapshot(1, 0, 0, 0, gb)];
        assert_eq!(snaps[0].cache_hits, 2);
        assert_eq!(snaps[0].cache_evictions, 3);
        assert_eq!(snaps[0].deferred_flushes, 2);
        assert!((snaps[0].cache_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        let t = CacheTotals::from_shards(&snaps);
        assert_eq!(t.hits, 3);
        assert_eq!(t.misses, 2);
        assert_eq!(t.admissions, 1);
        assert_eq!(t.evictions, 3);
        assert_eq!(t.deferred_flushes, 2);
        assert_eq!(t.cached_blocks, 6);
        assert_eq!(t.cached_bytes, 384);
        assert_eq!(t.dirty_blocks, 1);
        assert_eq!(t.dirty_bytes, 64);
        assert!((t.hit_rate() - 0.6).abs() < 1e-12);
        assert_eq!(CacheTotals::default().hit_rate(), 0.0);
    }

    #[test]
    fn integrity_counters_accumulate_sum_and_survive_absorb() {
        let a = ShardMetrics::new();
        a.scrubbed();
        a.scrubbed();
        a.corrupt_detected();
        a.quarantined();
        let b = ShardMetrics::new();
        b.scrubbed();
        b.healed();
        let snaps =
            vec![a.snapshot(0, 0, 0, 0, CacheGauges::default()), b.snapshot(1, 0, 0, 0, CacheGauges::default())];
        assert_eq!(snaps[0].scrubbed, 2);
        assert_eq!(snaps[0].corrupt_detected, 1);
        assert_eq!(snaps[0].quarantined, 1);
        assert_eq!(snaps[1].healed, 1);
        let t = IntegrityTotals::from_shards(&snaps);
        assert_eq!(t, IntegrityTotals { scrubbed: 3, corrupt_detected: 1, healed: 1, quarantined: 1 });
        // a shard resize folds retired shards' history in
        a.absorb(&b);
        let s = a.snapshot(0, 0, 0, 0, CacheGauges::default());
        assert_eq!((s.scrubbed, s.healed), (3, 1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.page(4096, 2048, 1000);
        m.page(4096, 1024, 1000);
        m.analysis(true);
        m.analysis(false);
        m.analysis_skipped();
        m.recompression();
        m.block_read(100);
        m.block_read(300);
        m.block_write(500);
        m.write_error();
        let s = m.snapshot();
        assert_eq!(s.write_errors, 1);
        assert_eq!(s.block_reads, 2);
        assert_eq!(s.block_read_mean_ns(), 200.0);
        assert_eq!(s.block_writes, 1);
        assert_eq!(s.block_write_mean_ns(), 500.0);
        assert_eq!(s.pages_in, 2);
        assert_eq!(s.bytes_in, 8192);
        assert_eq!(s.bytes_out, 3072);
        assert_eq!(s.analyses, 2);
        assert_eq!(s.analyses_skipped, 1);
        assert_eq!(s.table_swaps, 1);
        assert_eq!(s.table_rejects, 1);
        assert_eq!(s.recompressions, 1);
        assert!((s.ratio() - 8192.0 / 3072.0).abs() < 1e-12);
        assert!(s.compress_mib_s() > 0.0);
    }

    #[test]
    fn empty_snapshot_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.ratio(), 1.0);
        assert_eq!(s.compress_mib_s(), 0.0);
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = std::sync::Arc::clone(&m);
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.page(64, 32, 10);
                    }
                });
            }
        });
        assert_eq!(m.snapshot().pages_in, 8000);
    }
}
