//! Declarative command-line parsing (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with defaults, positional arguments, and generated `--help` text.
//!
//! ```
//! use gbdi::cli::{App, Arg};
//! let app = App::new("demo", "demo tool")
//!     .arg(Arg::opt("size", "64", "image size in MiB"))
//!     .arg(Arg::flag("verbose", "chatty output"));
//! let m = app.parse_from(vec!["--size".into(), "128".into()]).unwrap();
//! assert_eq!(m.get_u64("size"), 128);
//! assert!(!m.get_flag("verbose"));
//! ```

use std::collections::BTreeMap;

/// Argument specification.
#[derive(Debug, Clone)]
pub struct Arg {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
    positional: bool,
    required: bool,
}

impl Arg {
    /// `--name <value>` option with a default.
    pub fn opt(name: &str, default: &str, help: &str) -> Self {
        Arg {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
            positional: false,
            required: false,
        }
    }

    /// `--name <value>` option that must be provided.
    pub fn req(name: &str, help: &str) -> Self {
        Arg {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
            positional: false,
            required: true,
        }
    }

    /// Boolean `--name` flag.
    pub fn flag(name: &str, help: &str) -> Self {
        Arg {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
            positional: false,
            required: false,
        }
    }

    /// Required positional argument.
    pub fn pos(name: &str, help: &str) -> Self {
        Arg {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
            positional: true,
            required: true,
        }
    }
}

/// Parsed matches.
#[derive(Debug, Default)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

impl Matches {
    /// String value of an option/positional (panics if undeclared).
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("argument '{name}' not declared or missing"))
    }

    /// Optional string value.
    pub fn try_get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Value parsed as u64 (accepts `_` separators and `k/m/g` suffixes).
    pub fn get_u64(&self, name: &str) -> u64 {
        parse_u64(self.get(name)).unwrap_or_else(|e| panic!("--{name}: {e}"))
    }

    /// Value parsed as usize.
    pub fn get_usize(&self, name: &str) -> usize {
        self.get_u64(name) as usize
    }

    /// Value parsed as f64.
    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name}: expected float"))
    }

    /// Whether a flag was passed.
    pub fn get_flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }
}

/// Parse `123`, `4_096`, `64k`, `16m`, `2g` into a u64.
pub fn parse_u64(s: &str) -> Result<u64, String> {
    let s = s.trim().to_ascii_lowercase().replace('_', "");
    let (num, mult) = match s.chars().last() {
        Some('k') => (&s[..s.len() - 1], 1u64 << 10),
        Some('m') => (&s[..s.len() - 1], 1u64 << 20),
        Some('g') => (&s[..s.len() - 1], 1u64 << 30),
        _ => (s.as_str(), 1),
    };
    num.parse::<u64>().map(|v| v * mult).map_err(|_| format!("'{s}' is not an integer"))
}

/// A (sub)command: args + help.
pub struct App {
    name: String,
    about: String,
    args: Vec<Arg>,
    subcommands: Vec<App>,
}

/// Result of parsing an [`App`] with subcommands.
pub struct Parsed {
    /// Subcommand name (empty if the root matched).
    pub command: String,
    /// Matches for the selected (sub)command.
    pub matches: Matches,
}

impl App {
    /// New app/subcommand.
    pub fn new(name: &str, about: &str) -> Self {
        App { name: name.into(), about: about.into(), args: Vec::new(), subcommands: Vec::new() }
    }

    /// Declare an argument.
    pub fn arg(mut self, a: Arg) -> Self {
        self.args.push(a);
        self
    }

    /// Declare a subcommand.
    pub fn subcommand(mut self, s: App) -> Self {
        self.subcommands.push(s);
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {} ", self.name, self.about, self.name);
        if !self.subcommands.is_empty() {
            out.push_str("<COMMAND> ");
        }
        for a in &self.args {
            if a.positional {
                out.push_str(&format!("<{}> ", a.name));
            }
        }
        out.push_str("[OPTIONS]\n");
        if !self.subcommands.is_empty() {
            out.push_str("\nCOMMANDS:\n");
            for s in &self.subcommands {
                out.push_str(&format!("  {:<18} {}\n", s.name, s.about));
            }
        }
        if !self.args.is_empty() {
            out.push_str("\nOPTIONS:\n");
            for a in &self.args {
                let lhs = if a.positional {
                    format!("<{}>", a.name)
                } else if a.is_flag {
                    format!("--{}", a.name)
                } else {
                    format!("--{} <v>", a.name)
                };
                let def = a.default.as_ref().map(|d| format!(" [default: {d}]")).unwrap_or_default();
                out.push_str(&format!("  {:<22} {}{}\n", lhs, a.help, def));
            }
        }
        out
    }

    /// Parse raw args (without argv[0]). Returns Err(help/usage message) on
    /// problems or `--help`.
    pub fn parse_from(&self, argv: Vec<String>) -> Result<Matches, String> {
        let mut m = Matches::default();
        for a in &self.args {
            if let Some(d) = &a.default {
                m.values.insert(a.name.clone(), d.clone());
            }
            if a.is_flag {
                m.flags.insert(a.name.clone(), false);
            }
        }
        let mut positionals: Vec<&Arg> = self.args.iter().filter(|a| a.positional).collect();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.help());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .args
                    .iter()
                    .find(|a| a.name == key && !a.positional)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.help()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    m.flags.insert(key, true);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{key} needs a value"))?,
                    };
                    m.values.insert(key, v);
                }
            } else {
                let spec = if positionals.is_empty() {
                    return Err(format!("unexpected argument '{tok}'\n\n{}", self.help()));
                } else {
                    positionals.remove(0)
                };
                m.values.insert(spec.name.clone(), tok);
            }
        }
        for a in &self.args {
            if a.required && !m.values.contains_key(&a.name) {
                return Err(format!("missing required argument '{}'\n\n{}", a.name, self.help()));
            }
        }
        Ok(m)
    }

    /// Parse with subcommand dispatch. First non-flag token selects the
    /// subcommand; remaining tokens are parsed against it.
    pub fn parse_subcommands(&self, mut argv: Vec<String>) -> Result<Parsed, String> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
            return Err(self.help());
        }
        let cmd = argv.remove(0);
        let sub = self
            .subcommands
            .iter()
            .find(|s| s.name == cmd)
            .ok_or_else(|| format!("unknown command '{cmd}'\n\n{}", self.help()))?;
        let matches = sub.parse_from(argv)?;
        Ok(Parsed { command: cmd, matches })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> App {
        App::new("demo", "test app")
            .arg(Arg::opt("size", "64", "size"))
            .arg(Arg::flag("verbose", "chatty"))
            .arg(Arg::req("out", "output path"))
            .arg(Arg::pos("input", "input path"))
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let m = demo().parse_from(sv(&["in.bin", "--out", "o.bin"])).unwrap();
        assert_eq!(m.get_u64("size"), 64);
        assert_eq!(m.get("input"), "in.bin");
        assert_eq!(m.get("out"), "o.bin");
        assert!(!m.get_flag("verbose"));
        let m = demo()
            .parse_from(sv(&["--size=128", "--verbose", "in.bin", "--out", "o"]))
            .unwrap();
        assert_eq!(m.get_u64("size"), 128);
        assert!(m.get_flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        let e = demo().parse_from(sv(&["in.bin"])).unwrap_err();
        assert!(e.contains("missing required"), "{e}");
    }

    #[test]
    fn unknown_option_errors() {
        let e = demo().parse_from(sv(&["--bogus", "1", "in", "--out", "o"])).unwrap_err();
        assert!(e.contains("unknown option"), "{e}");
    }

    #[test]
    fn help_requested() {
        let e = demo().parse_from(sv(&["--help"])).unwrap_err();
        assert!(e.contains("USAGE"), "{e}");
    }

    #[test]
    fn suffix_parsing() {
        assert_eq!(parse_u64("64k").unwrap(), 64 << 10);
        assert_eq!(parse_u64("16M").unwrap(), 16 << 20);
        assert_eq!(parse_u64("2g").unwrap(), 2 << 30);
        assert_eq!(parse_u64("4_096").unwrap(), 4096);
        assert!(parse_u64("abc").is_err());
    }

    #[test]
    fn subcommand_dispatch() {
        let app = App::new("tool", "root")
            .subcommand(App::new("gen", "generate").arg(Arg::opt("n", "1", "count")))
            .subcommand(App::new("run", "run"));
        let p = app.parse_subcommands(sv(&["gen", "--n", "5"])).unwrap();
        assert_eq!(p.command, "gen");
        assert_eq!(p.matches.get_u64("n"), 5);
        assert!(app.parse_subcommands(sv(&["nope"])).is_err());
        assert!(app.parse_subcommands(vec![]).is_err());
    }
}
