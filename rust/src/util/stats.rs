//! Sampling and descriptive statistics used by the background-analysis
//! plane (reservoir/stride samplers feeding k-means) and by the report
//! layer (histograms, percentiles, entropy).

use crate::util::prng::Rng;

/// Reservoir sampler: uniform sample of `k` items from a stream of unknown
/// length (Vitter's algorithm R). Deterministic given the `Rng`.
#[derive(Debug, Clone)]
pub struct Reservoir<T> {
    k: usize,
    seen: u64,
    items: Vec<T>,
}

impl<T: Copy> Reservoir<T> {
    /// Sampler keeping at most `k` items.
    pub fn new(k: usize) -> Self {
        Reservoir { k, seen: 0, items: Vec::with_capacity(k) }
    }

    /// Offer one stream item.
    #[inline]
    pub fn offer(&mut self, x: T, rng: &mut Rng) {
        self.seen += 1;
        if self.items.len() < self.k {
            self.items.push(x);
        } else {
            let j = rng.below(self.seen);
            if (j as usize) < self.k {
                self.items[j as usize] = x;
            }
        }
    }

    /// Total items offered.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// The current sample.
    pub fn items(&self) -> &[T] {
        &self.items
    }

    /// Consume into the sample vector.
    pub fn into_items(self) -> Vec<T> {
        self.items
    }
}

/// Deterministic strided sample: every `ceil(n/k)`-th element, up to `k`
/// items. Cheaper than a reservoir when the data is already materialized,
/// and what a memory controller would realistically implement.
pub fn stride_sample<T: Copy>(data: &[T], k: usize) -> Vec<T> {
    if data.is_empty() || k == 0 {
        return Vec::new();
    }
    if data.len() <= k {
        return data.to_vec();
    }
    let stride = data.len() / k;
    data.iter().step_by(stride.max(1)).take(k).copied().collect()
}

/// Mean of an f64 slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Geometric mean of positive values (0 if any non-positive / empty).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// `q`-quantile (0..=1) by linear interpolation over a *sorted* slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// `q`-quantile of unsorted u64 magnitudes via select-by-sort (n log n; the
/// analysis plane calls this on ≤64Ki samples, so simplicity wins).
pub fn quantile_u64(xs: &[u64], q: f64) -> u64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_unstable();
    let pos = (q.clamp(0.0, 1.0) * (v.len() - 1) as f64).round() as usize;
    v[pos]
}

/// Shannon entropy (bits/byte) of a byte slice — used to characterize
/// workload images in reports.
pub fn byte_entropy(data: &[u8]) -> f64 {
    if data.is_empty() {
        return 0.0;
    }
    let mut counts = [0u64; 256];
    for &b in data {
        counts[b as usize] += 1;
    }
    let n = data.len() as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

/// Fixed-bin histogram over u64 values.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Inclusive lower edge of bin 0.
    pub lo: u64,
    /// Bin width.
    pub width: u64,
    /// Counts per bin; the last bin also catches overflow.
    pub bins: Vec<u64>,
    /// Count of values below `lo`.
    pub underflow: u64,
    total: u64,
}

impl Histogram {
    /// Histogram with `n` bins of `width` starting at `lo`.
    pub fn new(lo: u64, width: u64, n: usize) -> Self {
        assert!(width > 0 && n > 0);
        Histogram { lo, width, bins: vec![0; n], underflow: 0, total: 0 }
    }

    /// Add one observation.
    #[inline]
    pub fn add(&mut self, x: u64) {
        self.total += 1;
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        let idx = ((x - self.lo) / self.width) as usize;
        let last = self.bins.len() - 1;
        self.bins[idx.min(last)] += 1;
    }

    /// Total observations.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Fraction of observations in bin `i`.
    pub fn frac(&self, i: usize) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.bins[i] as f64 / self.total as f64
        }
    }
}

/// Online mean/min/max/count accumulator (for metrics counters).
#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    /// Number of observations.
    pub n: u64,
    /// Sum of observations.
    pub sum: f64,
    /// Minimum (f64::INFINITY when empty).
    pub min: f64,
    /// Maximum (f64::NEG_INFINITY when empty).
    pub max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Summary { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    #[inline]
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Merge another summary into this one.
    pub fn merge(&mut self, other: &Summary) {
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_keeps_k_and_is_roughly_uniform() {
        let mut rng = Rng::new(5);
        let mut res = Reservoir::new(100);
        for i in 0..10_000u64 {
            res.offer(i, &mut rng);
        }
        assert_eq!(res.items().len(), 100);
        assert_eq!(res.seen(), 10_000);
        let m = mean(&res.items().iter().map(|&x| x as f64).collect::<Vec<_>>());
        assert!((m - 5000.0).abs() < 900.0, "mean {m}");
    }

    #[test]
    fn reservoir_small_stream() {
        let mut rng = Rng::new(5);
        let mut res = Reservoir::new(10);
        for i in 0..3u64 {
            res.offer(i, &mut rng);
        }
        assert_eq!(res.items(), &[0, 1, 2]);
    }

    #[test]
    fn stride_sample_bounds() {
        let data: Vec<u32> = (0..1000).collect();
        let s = stride_sample(&data, 64);
        assert_eq!(s.len(), 64);
        assert_eq!(s[0], 0);
        let s2 = stride_sample(&data, 5000);
        assert_eq!(s2.len(), 1000);
        assert!(stride_sample(&data, 0).is_empty());
        assert!(stride_sample::<u32>(&[], 8).is_empty());
    }

    #[test]
    fn quantiles() {
        let sorted: Vec<f64> = (0..=100).map(|x| x as f64).collect();
        assert_eq!(quantile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(quantile_sorted(&sorted, 1.0), 100.0);
        assert_eq!(quantile_sorted(&sorted, 0.5), 50.0);
        assert!((quantile_sorted(&sorted, 0.95) - 95.0).abs() < 1e-9);
        assert_eq!(quantile_u64(&[5, 1, 9, 3, 7], 0.5), 5);
    }

    #[test]
    fn entropy_extremes() {
        assert_eq!(byte_entropy(&[]), 0.0);
        assert_eq!(byte_entropy(&[7u8; 4096]), 0.0);
        let all: Vec<u8> = (0..=255).collect();
        assert!((byte_entropy(&all) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(10, 5, 4); // bins [10,15) [15,20) [20,25) [25,inf)
        for x in [3, 10, 14, 15, 24, 25, 1000] {
            h.add(x);
        }
        assert_eq!(h.underflow, 1);
        assert_eq!(h.bins, vec![2, 1, 1, 2]);
        assert_eq!(h.total(), 7);
        assert!((h.frac(0) - 2.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn summary_and_merge() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        for x in [1.0, 2.0, 3.0] {
            a.add(x);
        }
        for x in [10.0, 20.0] {
            b.add(x);
        }
        a.merge(&b);
        assert_eq!(a.n, 5);
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 20.0);
        assert!((a.mean() - 7.2).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(geomean(&[1.0, 0.0]), 0.0);
    }

    #[test]
    fn stddev_basic() {
        assert_eq!(stddev(&[5.0]), 0.0);
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }
}
