//! A small fixed-size thread pool built on `std::thread` + channels (tokio
//! is unavailable offline). The coordinator uses it for compression
//! workers; benches use [`parallel_map_chunks`] for data-parallel sweeps.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool with a shared MPMC job queue (single `Receiver`
/// behind a mutex — contention is negligible at our job granularity).
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn a pool with `n` worker threads (min 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::Builder::new()
                    .name(format!("gbdi-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                queued.fetch_sub(1, Ordering::Release);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, queued }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::AcqRel);
        self.tx
            .as_ref()
            .expect("pool not shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Busy-wait (with yields) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Data-parallel map over chunks of `items`: splits into `threads` nearly
/// equal contiguous chunks, applies `f` to each chunk on its own scoped
/// thread, and concatenates results in order. `f` receives
/// `(chunk_index, &[T])`.
pub fn parallel_map_chunks<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &[T]) -> Vec<R> + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads == 1 {
        return f(0, items);
    }
    let chunk = (items.len() + threads - 1) / threads;
    // ceil-division can yield fewer pieces than threads (e.g. 12 items on
    // 8 threads -> chunk 2 -> 6 pieces); size the slots to the pieces so
    // the trailing slots don't stay None and panic below.
    let n_pieces = (items.len() + chunk - 1) / chunk;
    let mut out: Vec<Option<Vec<R>>> = (0..n_pieces).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut pending = Vec::new();
        for (i, (slot, piece)) in out.iter_mut().zip(items.chunks(chunk)).enumerate() {
            let f = &f;
            pending.push(scope.spawn(move || {
                *slot = Some(f(i, piece));
            }));
        }
        for h in pending {
            h.join().expect("chunk worker panicked");
        }
    });
    out.into_iter().flat_map(|o| o.expect("all chunks ran")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_shutdown_joins() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop waits for all jobs
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let doubled = parallel_map_chunks(&items, 7, |_, chunk| {
            chunk.iter().map(|x| x * 2).collect()
        });
        assert_eq!(doubled, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_fewer_pieces_than_threads() {
        // 12 items / 8 threads -> chunk 2 -> 6 pieces; must not panic on
        // the 2 never-filled slots (regression: "all chunks ran" expect)
        let items: Vec<u64> = (0..12).collect();
        let r = parallel_map_chunks(&items, 8, |_, chunk| chunk.to_vec());
        assert_eq!(r, items);
        // and the pathological 3 items / 2 threads -> chunk 2 -> 2 pieces
        let items = [7u32, 8, 9];
        let r = parallel_map_chunks(&items, 2, |_, c| c.to_vec());
        assert_eq!(r, items);
    }

    #[test]
    fn parallel_map_single_thread_and_empty() {
        let items = [1u32, 2, 3];
        let r = parallel_map_chunks(&items, 1, |_, c| c.to_vec());
        assert_eq!(r, items);
        let empty: Vec<u32> = vec![];
        let r = parallel_map_chunks(&empty, 4, |_, c| c.to_vec());
        assert!(r.is_empty());
    }

    #[test]
    fn pool_min_one_thread() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
    }
}
