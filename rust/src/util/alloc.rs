//! A counting global allocator for allocation-budget tests and benches.
//!
//! The Frame API's contract is *zero heap allocations* on the
//! steady-state read/estimate paths; asserting that requires observing
//! the allocator. Register [`CountingAlloc`] as the `#[global_allocator]`
//! of a test or bench **binary** (never the library), then diff
//! [`CountingAlloc::allocations`] around the code under test:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: gbdi::util::alloc::CountingAlloc = gbdi::util::alloc::CountingAlloc::new();
//!
//! let before = gbdi::util::alloc::CountingAlloc::allocations();
//! hot_path();
//! assert_eq!(gbdi::util::alloc::CountingAlloc::allocations(), before);
//! ```
//!
//! Counters are global (one allocator per process) and monotonically
//! increasing; `realloc` counts as one allocation event.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);
static ALLOCATED_BYTES: AtomicU64 = AtomicU64::new(0);

/// [`System`] allocator wrapper that counts allocation events.
pub struct CountingAlloc;

impl CountingAlloc {
    /// Const constructor for `#[global_allocator]` statics.
    pub const fn new() -> Self {
        CountingAlloc
    }

    /// Allocation events since process start (allocs + reallocs).
    pub fn allocations() -> u64 {
        ALLOCATIONS.load(Ordering::Relaxed)
    }

    /// Bytes requested since process start.
    pub fn allocated_bytes() -> u64 {
        ALLOCATED_BYTES.load(Ordering::Relaxed)
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        CountingAlloc::new()
    }
}

// SAFETY: delegates directly to `System`; the counters are lock-free
// atomics and never allocate.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        ALLOCATED_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        ALLOCATED_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        ALLOCATED_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}
