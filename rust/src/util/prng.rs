//! Deterministic pseudo-random generation (no `rand` crate offline).
//!
//! [`Rng`] is xoshiro256** seeded through SplitMix64 — fast, high quality,
//! and fully reproducible from a single `u64` seed. On top of the raw
//! generator we provide the distributions the workload generators need:
//! bounded uniforms, normals (Box–Muller), Zipf (rejection-inversion-lite),
//! Pareto, and weighted choice.

/// xoshiro256** generator; every workload image and every benchmark input
/// in this repo derives from one of these, so runs are reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is fine:
    /// SplitMix64 expands it into a full non-zero state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child generator (for per-region / per-thread
    /// streams) without correlating with the parent's future output.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw 64 bits (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`. Uses Lemire's multiply-shift rejection to
    /// avoid modulo bias. `bound` must be > 0.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi)` (u64). `hi` must be > `lo`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform signed integer in `[lo, hi)`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo.wrapping_add(self.below((hi - lo) as u64) as i64)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` (s > 0).
    /// Uses the inverse-CDF over a precomputable harmonic approximation —
    /// exact enough for workload synthesis and O(1) per draw.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n > 0);
        if n == 1 {
            return 0;
        }
        // Approximate inverse CDF of the Zipf distribution via the
        // continuous analogue (bounded Pareto); clamp to the support.
        let nf = n as f64;
        let u = self.f64();
        let k = if (s - 1.0).abs() < 1e-9 {
            // H(x) ~ ln(x+1)
            ((nf + 1.0).powf(u) - 1.0).floor()
        } else {
            let t = 1.0 - s;
            (((nf + 1.0).powf(t) - 1.0) * u + 1.0).powf(1.0 / t).floor() - 1.0
        };
        (k.max(0.0) as u64).min(n - 1)
    }

    /// Pareto-distributed f64 with scale `xm` and shape `alpha`.
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = 1.0 - self.f64();
        xm / u.powf(1.0 / alpha)
    }

    /// Pick an index according to non-negative `weights` (need not sum to 1).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a byte slice with random bytes.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(13);
        let n = 100u64;
        let mut counts = vec![0u64; n as usize];
        for _ in 0..20_000 {
            let k = r.zipf(n, 1.1);
            assert!(k < n);
            counts[k as usize] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[60].saturating_sub(1));
        // head should dominate
        let head: u64 = counts[..10].iter().sum();
        let tail: u64 = counts[50..].iter().sum();
        assert!(head > tail * 2, "head={head} tail={tail}");
    }

    #[test]
    fn zipf_head_mass_matches_closed_form() {
        // The generator draws from the continuous bounded-Pareto inverse
        // CDF, so the share of draws landing in the top `m` of `n` ranks
        // has a closed form:
        //   s = 1:  P(k < m) = ln(m+1) / ln(n+1)
        //   s != 1: P(k < m) = ((m+1)^t - 1) / ((n+1)^t - 1),  t = 1 - s
        // Check the top-1% head mass against it for the exponents the
        // serving bench sweeps.
        let (n, m, draws) = (1000u64, 10u64, 200_000u64);
        let expected = |s: f64| {
            if (s - 1.0).abs() < 1e-9 {
                ((m + 1) as f64).ln() / ((n + 1) as f64).ln()
            } else {
                let t = 1.0 - s;
                (((m + 1) as f64).powf(t) - 1.0) / (((n + 1) as f64).powf(t) - 1.0)
            }
        };
        let head = |s: f64, seed: u64| {
            let mut r = Rng::new(seed);
            let hits = (0..draws).filter(|_| r.zipf(n, s) < m).count();
            hits as f64 / draws as f64
        };
        let (h10, e10) = (head(1.0, 17), expected(1.0));
        let (h12, e12) = (head(1.2, 19), expected(1.2));
        assert!((h10 - e10).abs() < 0.02, "s=1.0: head {h10} vs closed form {e10}");
        assert!((h12 - e12).abs() < 0.02, "s=1.2: head {h12} vs closed form {e12}");
        // anchor the closed form itself: the top 1% of ranks carries
        // ~34.7% of the mass at s=1.0 and ~50.9% at s=1.2
        assert!((e10 - 0.347).abs() < 0.005, "e10={e10}");
        assert!((e12 - 0.509).abs() < 0.005, "e12={e12}");
        // a steeper exponent concentrates the head
        assert!(h12 > h10 + 0.1, "h10={h10} h12={h12}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0u32; 3];
        for _ in 0..10_000 {
            c[r.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 5, "c={c:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffled order differs");
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Rng::new(21);
        let mut a = parent.fork(0);
        let mut b = parent.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(1);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
