//! Bit-level packed stream I/O — the substrate under every codec in this
//! repo (GBDI, BDI, FPC, Huffman).
//!
//! The stream is **LSB-first within a little-endian u64 accumulator**: the
//! first bit written is the lowest bit of the first byte. The writer's
//! accumulator drains eight bytes at a time (`to_le_bytes` +
//! `extend_from_slice`), never byte-by-byte; the reader refills with one
//! unaligned 8-byte load. Fields up to 57 bits read in a single shift-or
//! (the refill keeps at least 57 valid bits available); the writer takes
//! up to 64 bits per `put`. Bulk block payloads ride [`BitWriter::put_bytes`]
//! and [`BitReader::read_bytes`], which degrade to a plain `memcpy` when
//! the stream is byte-aligned. See DESIGN.md §9 for the layout invariants.

/// Append-only bit writer over a growable byte buffer.
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bit accumulator; low `fill` bits are valid and not yet flushed.
    /// Invariant between calls: `fill <= 63`.
    acc: u64,
    fill: u32,
}

impl BitWriter {
    /// New empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// New writer with reserved capacity (bytes).
    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bytes), acc: 0, fill: 0 }
    }

    /// Number of bits written so far.
    #[inline]
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.fill as usize
    }

    /// Write the low `n` bits of `v` (0 <= n <= 64). Bits above `n` in `v`
    /// must be zero (debug-asserted) — callers mask.
    #[inline]
    pub fn put(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        debug_assert!(n == 64 || v < (1u64 << n), "value {v} does not fit {n} bits");
        if n == 0 {
            return;
        }
        // `fill <= 63`, so the shift is defined; bits past 63 fall off the
        // top and are re-emitted from `v` after the word flush below.
        self.acc |= v << self.fill;
        let total = self.fill + n;
        if total >= 64 {
            self.buf.extend_from_slice(&self.acc.to_le_bytes());
            self.fill = total - 64;
            // 64 - old_fill bits of `v` were flushed; keep the rest.
            self.acc = if self.fill == 0 { 0 } else { v >> (n - self.fill) };
        } else {
            self.fill = total;
        }
    }

    /// Write a single bit.
    #[inline]
    pub fn put_bit(&mut self, b: bool) {
        self.put(b as u64, 1);
    }

    /// Write `n` bits of a signed value in offset-binary (excess-2^(n-1)):
    /// representable range is `[-2^(n-1), 2^(n-1) - 1]`.
    #[inline]
    pub fn put_signed(&mut self, v: i64, n: u32) {
        debug_assert!(n >= 1 && n <= 63);
        let bias = 1i64 << (n - 1);
        debug_assert!(v >= -bias && v < bias, "signed {v} does not fit {n} bits");
        self.put((v + bias) as u64, n);
    }

    /// Append whole bytes, equivalent to `put(b, 8)` per byte but bulk:
    /// on a byte-aligned stream this is a single `extend_from_slice`
    /// (memcpy); off alignment it moves eight bytes per shift through the
    /// accumulator. The RAW-block fast path of every codec.
    ///
    /// ```
    /// use gbdi::util::bits::{BitReader, BitWriter};
    ///
    /// let mut w = BitWriter::new();
    /// w.put(0b101, 3); // stream is now mid-byte: shifted-copy slow path
    /// w.put_bytes(&[0xAB, 0xCD, 0xEF]);
    /// let bytes = w.finish();
    /// let mut r = BitReader::new(&bytes);
    /// assert_eq!(r.get(3).unwrap(), 0b101);
    /// let mut back = [0u8; 3];
    /// r.read_bytes(&mut back).unwrap();
    /// assert_eq!(back, [0xAB, 0xCD, 0xEF]);
    /// ```
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        if self.fill % 8 == 0 {
            // Byte-aligned: drain the accumulator's whole bytes, then memcpy.
            while self.fill > 0 {
                self.buf.push(self.acc as u8);
                self.acc >>= 8;
                self.fill -= 8;
            }
            self.buf.extend_from_slice(bytes);
            return;
        }
        let mut words = bytes.chunks_exact(8);
        for c in &mut words {
            self.put(u64::from_le_bytes(c.try_into().unwrap()), 64);
        }
        let rest = words.remainder();
        if !rest.is_empty() {
            let mut v = 0u64;
            for (i, &b) in rest.iter().enumerate() {
                v |= (b as u64) << (8 * i as u32);
            }
            self.put(v, 8 * rest.len() as u32);
        }
    }

    /// Finish the stream, zero-padding to a byte boundary, and return the
    /// packed bytes.
    pub fn finish(mut self) -> Vec<u8> {
        self.flush_to_byte();
        self.buf
    }

    /// Reset to an empty stream, keeping the buffer's capacity — the
    /// reuse hook [`crate::codec::Scratch`] is built on (per-block
    /// encodes in a loop must not re-allocate).
    pub fn clear(&mut self) {
        self.buf.clear();
        self.acc = 0;
        self.fill = 0;
    }

    /// Zero-pad to a byte boundary in place (non-consuming [`Self::finish`]):
    /// after this call [`Self::bytes`] exposes the complete packed stream
    /// and further `put`s continue byte-aligned.
    pub fn flush_to_byte(&mut self) {
        while self.fill > 0 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.fill = self.fill.saturating_sub(8);
        }
        self.acc = 0;
    }

    /// The packed bytes written so far. Only whole bytes are visible —
    /// call [`Self::flush_to_byte`] first if the stream may end mid-byte.
    pub fn bytes(&self) -> &[u8] {
        debug_assert_eq!(self.fill, 0, "unflushed bits; call flush_to_byte first");
        &self.buf
    }

    /// Append `nbits` bits copied from `src` starting at bit offset
    /// `bit_off` (same LSB-first layout). The compaction primitive under
    /// [`crate::frame::Frame::to_container`]: blocks are moved between
    /// streams without re-encoding. After aligning the source cursor to a
    /// byte boundary the copy proceeds a word (or, when the writer is
    /// also aligned, a memcpy) at a time.
    ///
    /// Panics if `src` holds fewer than `bit_off + nbits` bits.
    pub fn append_from(&mut self, src: &[u8], bit_off: usize, nbits: u64) {
        assert!(
            (src.len() as u64) * 8 >= bit_off as u64 + nbits,
            "append_from: source exhausted"
        );
        let mut byte = bit_off / 8;
        let sub = (bit_off % 8) as u32;
        let mut rem = nbits;
        if sub != 0 {
            let take = rem.min((8 - sub) as u64) as u32;
            self.put(((src[byte] >> sub) as u64) & ((1u64 << take) - 1), take);
            rem -= take as u64;
            byte += 1;
        }
        if rem == 0 {
            return;
        }
        // Source cursor is now byte-aligned at `byte`; put_bytes picks the
        // memcpy or shifted-word path from the writer's own alignment.
        let whole = (rem / 8) as usize;
        self.put_bytes(&src[byte..byte + whole]);
        byte += whole;
        rem %= 8;
        if rem > 0 {
            self.put((src[byte] as u64) & ((1u64 << rem) - 1), rem as u32);
        }
    }

    /// Current byte length if finished now.
    pub fn byte_len(&self) -> usize {
        (self.bit_len() + 7) / 8
    }
}

/// Gather 64 bits of `src` starting at bit offset `bit` (LSB-first).
/// Caller guarantees `bit + 64 <= src.len() * 8`; for an unaligned `bit`
/// that bound also puts the ninth byte in range.
#[inline]
fn load_bits64(src: &[u8], bit: usize) -> u64 {
    let b = bit / 8;
    let sh = (bit % 8) as u32;
    let lo = u64::from_le_bytes(src[b..b + 8].try_into().unwrap());
    if sh == 0 {
        lo
    } else {
        (lo >> sh) | ((src[b + 8] as u64) << (64 - sh))
    }
}

/// Copy one sub-byte piece (up to the next `dst` byte boundary) from
/// `src` bit `spos` to `dst` bit `dpos`; returns the bits copied.
#[inline]
fn copy_piece(dst: &mut [u8], dpos: usize, src: &[u8], spos: usize, max: usize) -> usize {
    let byte = dpos / 8;
    let bit = (dpos % 8) as u32;
    let take = (8 - bit).min(max.min(8) as u32);
    let sb = spos / 8;
    let so = (spos % 8) as u32;
    let mut v = (src[sb] >> so) as u16;
    if so + take > 8 {
        v |= (src[sb + 1] as u16) << (8 - so);
    }
    let keep = ((1u16 << take) - 1) as u8;
    let v = (v as u8) & keep;
    dst[byte] = (dst[byte] & !(keep << bit)) | (v << bit);
    take as usize
}

/// Copy `nbits` bits from `src` starting at bit `src_pos` into `dst`
/// starting at bit `dst_pos` (both LSB-first packed); bits of `dst`
/// outside the window are preserved. Word-at-a-time: after a sub-byte
/// head aligns the destination cursor, the middle runs 64 bits per
/// iteration (one unaligned gather, one aligned 8-byte store).
///
/// The general splice primitive; [`overwrite_bits`] is the `src_pos = 0`
/// special case used by [`crate::frame::Frame::write_block`].
///
/// ```
/// use gbdi::util::bits::copy_bits;
///
/// let src = [0b1111_0110u8, 0b1010_1010];
/// let mut dst = [0u8; 2];
/// // move 9 bits starting at src bit 2 to dst bit 3
/// copy_bits(&mut dst, 3, &src, 2, 9);
/// for i in 0..9 {
///     let s = (src[(2 + i) / 8] >> ((2 + i) % 8)) & 1;
///     let d = (dst[(3 + i) / 8] >> ((3 + i) % 8)) & 1;
///     assert_eq!(s, d, "bit {i}");
/// }
/// ```
pub fn copy_bits(dst: &mut [u8], dst_pos: usize, src: &[u8], src_pos: usize, nbits: usize) {
    debug_assert!(dst_pos + nbits <= dst.len() * 8, "copy_bits: window past dst");
    debug_assert!(src_pos + nbits <= src.len() * 8, "copy_bits: src too short");
    let mut done = 0usize;
    // Head: per-piece until the destination cursor is byte-aligned.
    while done < nbits && (dst_pos + done) % 8 != 0 {
        done += copy_piece(dst, dst_pos + done, src, src_pos + done, nbits - done);
    }
    // Middle: 64 bits per iteration onto the aligned destination.
    while nbits - done >= 64 {
        let v = load_bits64(src, src_pos + done);
        let b = (dst_pos + done) / 8;
        dst[b..b + 8].copy_from_slice(&v.to_le_bytes());
        done += 64;
    }
    // Tail: fewer than 64 bits left (at most 8 pieces).
    while done < nbits {
        done += copy_piece(dst, dst_pos + done, src, src_pos + done, nbits - done);
    }
}

/// Overwrite `nbits` bits of `dst` starting at bit `pos` with the first
/// `nbits` bits of `src` (both LSB-first packed). Bits of `dst` outside
/// the window are preserved — this is the read-modify-write splice under
/// [`crate::frame::Frame::write_block`]'s in-place path, where a block's
/// new encoding lands inside its old bit span without disturbing the
/// neighbouring blocks that share its boundary bytes.
pub fn overwrite_bits(dst: &mut [u8], pos: usize, src: &[u8], nbits: usize) {
    debug_assert!(nbits <= src.len() * 8, "overwrite_bits: src too short");
    copy_bits(dst, pos, src, 0, nbits);
}

/// Zig-zag encode a signed integer to an unsigned one (small magnitudes →
/// small codes); inverse of [`zigzag_decode`].
#[inline]
pub fn zigzag_encode(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag_encode`].
#[inline]
pub fn zigzag_decode(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

/// Minimum number of bits needed to store `v` in offset-binary signed form
/// (i.e. smallest n with `-2^(n-1) <= v < 2^(n-1)`); 0 for v == 0.
#[inline]
pub fn signed_width(v: i64) -> u32 {
    if v == 0 {
        0
    } else if v > 0 {
        64 - (v as u64).leading_zeros() + 1
    } else {
        64 - ((-(v + 1)) as u64).leading_zeros() + 1
    }
}

/// Error from [`BitReader`] when the stream runs out.
#[derive(Debug, PartialEq, Eq)]
pub struct OutOfBits;

/// Sequential bit reader over a byte slice (same layout as [`BitWriter`]).
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    data: &'a [u8],
    /// Next unread byte index.
    pos: usize,
    acc: u64,
    fill: u32,
}

impl<'a> BitReader<'a> {
    /// Reader over `data` starting at bit 0.
    pub fn new(data: &'a [u8]) -> Self {
        BitReader { data, pos: 0, acc: 0, fill: 0 }
    }

    /// Bits consumed so far.
    #[inline]
    pub fn bit_pos(&self) -> usize {
        self.pos * 8 - self.fill as usize
    }

    /// Bits remaining.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.data.len() * 8 - self.bit_pos()
    }

    #[inline]
    fn refill(&mut self) {
        // Fast path: bulk 8-byte unaligned load.
        if self.pos + 8 <= self.data.len() {
            let w = u64::from_le_bytes(self.data[self.pos..self.pos + 8].try_into().unwrap());
            let take = ((64 - self.fill) / 8) as usize; // whole bytes that fit
            let new_fill = self.fill + take as u32 * 8;
            let mask = if new_fill >= 64 { u64::MAX } else { (1u64 << new_fill) - 1 };
            self.acc |= w.wrapping_shl(self.fill) & mask;
            self.pos += take;
            self.fill = new_fill;
        } else {
            while self.fill <= 56 && self.pos < self.data.len() {
                self.acc |= (self.data[self.pos] as u64) << self.fill;
                self.pos += 1;
                self.fill += 8;
            }
        }
    }

    /// Read `n` bits (0 <= n <= 64).
    #[inline]
    pub fn get(&mut self, n: u32) -> Result<u64, OutOfBits> {
        debug_assert!(n <= 64);
        if n == 0 {
            return Ok(0);
        }
        if n <= 57 {
            if self.fill < n {
                self.refill();
                if self.fill < n {
                    return Err(OutOfBits);
                }
            }
            let v = self.acc & ((1u64 << n) - 1);
            self.acc >>= n;
            self.fill -= n;
            Ok(v)
        } else {
            let lo = self.get(32)?;
            let hi = self.get(n - 32)?;
            Ok(lo | (hi << 32))
        }
    }

    /// Read one bit.
    #[inline]
    pub fn get_bit(&mut self) -> Result<bool, OutOfBits> {
        Ok(self.get(1)? != 0)
    }

    /// Read an `n`-bit offset-binary signed value (see `put_signed`).
    #[inline]
    pub fn get_signed(&mut self, n: u32) -> Result<i64, OutOfBits> {
        debug_assert!(n >= 1 && n <= 63);
        let bias = 1i64 << (n - 1);
        Ok(self.get(n)? as i64 - bias)
    }

    /// Read exactly `out.len()` whole bytes, equivalent to `get(8)` per
    /// byte but bulk: on a byte-aligned stream one `copy_from_slice`
    /// (memcpy), off alignment seven bytes per accumulator refill. The
    /// RAW-block decode fast path. Fails without consuming a defined
    /// amount if the stream is short.
    ///
    /// ```
    /// use gbdi::util::bits::{BitReader, BitWriter};
    ///
    /// let mut w = BitWriter::new();
    /// w.put_bytes(&[1, 2, 3, 4]);
    /// let bytes = w.finish();
    /// let mut r = BitReader::new(&bytes);
    /// let mut out = [0u8; 4];
    /// r.read_bytes(&mut out).unwrap(); // byte-aligned: memcpy fast path
    /// assert_eq!(out, [1, 2, 3, 4]);
    /// assert!(r.read_bytes(&mut out).is_err()); // stream exhausted
    /// ```
    pub fn read_bytes(&mut self, out: &mut [u8]) -> Result<(), OutOfBits> {
        let bit = self.bit_pos();
        if bit % 8 == 0 {
            let b = bit / 8;
            if b + out.len() > self.data.len() {
                return Err(OutOfBits);
            }
            out.copy_from_slice(&self.data[b..b + out.len()]);
            self.pos = b + out.len();
            self.acc = 0;
            self.fill = 0;
            return Ok(());
        }
        let mut chunks = out.chunks_exact_mut(7);
        for c in &mut chunks {
            let v = self.get(56)?;
            c.copy_from_slice(&v.to_le_bytes()[..7]);
        }
        for b in chunks.into_remainder() {
            *b = self.get(8)? as u8;
        }
        Ok(())
    }

    /// Peek `n` bits (n <= 57) without consuming. Bits past the end read as
    /// zero (for Huffman-style table lookups near stream end).
    #[inline]
    pub fn peek(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        if self.fill < n {
            self.refill();
        }
        self.acc & ((1u64 << n) - 1)
    }

    /// Discard bits up to the next byte boundary (chunk realignment in
    /// parallel-compressed streams). No-op when already aligned.
    #[inline]
    pub fn skip_to_byte(&mut self) -> Result<(), OutOfBits> {
        let rem = (self.bit_pos() % 8) as u32;
        if rem != 0 {
            self.get(8 - rem)?;
        }
        Ok(())
    }

    /// Consume `n` bits previously peeked. `n` must be <= current fill.
    #[inline]
    pub fn consume(&mut self, n: u32) -> Result<(), OutOfBits> {
        if self.fill < n {
            self.refill();
            if self.fill < n {
                return Err(OutOfBits);
            }
        }
        self.acc >>= n;
        self.fill -= n;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_fixed_fields() {
        let mut w = BitWriter::new();
        w.put(0b101, 3);
        w.put(0xFFFF, 16);
        w.put(0, 0);
        w.put(1, 1);
        w.put(0x1234_5678_9ABC, 48);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(3).unwrap(), 0b101);
        assert_eq!(r.get(16).unwrap(), 0xFFFF);
        assert_eq!(r.get(0).unwrap(), 0);
        assert_eq!(r.get(1).unwrap(), 1);
        assert_eq!(r.get(48).unwrap(), 0x1234_5678_9ABC);
    }

    #[test]
    fn wire_layout_is_pinned_lsb_first() {
        // The exact byte values, not just a roundtrip: this is the layout
        // every checked-in golden fixture depends on.
        let mut w = BitWriter::new();
        w.put(0b101, 3);
        w.put(0b1010, 4);
        let bytes = w.finish();
        assert_eq!(bytes, vec![0b0101_0101]); // 101 then 1010, LSB-first
        let mut w = BitWriter::new();
        w.put(0xFF, 8);
        w.put(0b1, 1);
        assert_eq!(w.finish(), vec![0xFF, 0x01]);
        let mut w = BitWriter::new();
        w.put(0x0123_4567_89AB_CDEF, 64);
        assert_eq!(w.finish(), 0x0123_4567_89AB_CDEFu64.to_le_bytes().to_vec());
        // a 60-bit field crossing the accumulator flush boundary
        let mut w = BitWriter::new();
        w.put(0b1111, 4);
        w.put(0x0AAA_AAAA_AAAA_AAAA, 60);
        assert_eq!(w.finish(), vec![0xAF, 0xAA, 0xAA, 0xAA, 0xAA, 0xAA, 0xAA, 0xAA]);
    }

    #[test]
    fn roundtrip_64bit_fields() {
        let mut w = BitWriter::new();
        w.put(u64::MAX, 64);
        w.put(0xDEAD_BEEF_CAFE_F00D, 64);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(64).unwrap(), u64::MAX);
        assert_eq!(r.get(64).unwrap(), 0xDEAD_BEEF_CAFE_F00D);
    }

    #[test]
    fn roundtrip_random_mixed_widths() {
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let fields: Vec<(u64, u32)> = (0..rng.range(1, 100))
                .map(|_| {
                    let n = rng.range(1, 65) as u32;
                    let v = if n == 64 { rng.next_u64() } else { rng.next_u64() & ((1u64 << n) - 1) };
                    (v, n)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, n) in &fields {
                w.put(v, n);
            }
            let total_bits: usize = fields.iter().map(|&(_, n)| n as usize).sum();
            assert_eq!(w.bit_len(), total_bits);
            let bytes = w.finish();
            assert_eq!(bytes.len(), (total_bits + 7) / 8);
            let mut r = BitReader::new(&bytes);
            for &(v, n) in &fields {
                assert_eq!(r.get(n).unwrap(), v, "width {n}");
            }
        }
    }

    #[test]
    fn signed_roundtrip() {
        let mut w = BitWriter::new();
        let cases = [(-8i64, 4u32), (7, 4), (0, 1), (-1, 1), (-(1 << 30), 31), ((1 << 30) - 1, 31)];
        for &(v, n) in &cases {
            w.put_signed(v, n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &cases {
            assert_eq!(r.get_signed(n).unwrap(), v);
        }
    }

    #[test]
    fn out_of_bits_detected() {
        let bytes = BitWriter::new().finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(1), Err(OutOfBits));
        let mut w = BitWriter::new();
        w.put(3, 2);
        let bytes = w.finish(); // 1 byte, 6 bits padding
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(8).unwrap(), 3); // padding readable as zeros
        assert_eq!(r.get(1), Err(OutOfBits));
    }

    #[test]
    fn peek_consume_matches_get() {
        let mut rng = Rng::new(3);
        let vals: Vec<u64> = (0..64).map(|_| rng.next_u64() & 0x1FFF).collect();
        let mut w = BitWriter::new();
        for &v in &vals {
            w.put(v, 13);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(r.peek(13), v);
            r.consume(13).unwrap();
        }
    }

    #[test]
    fn put_bytes_matches_per_byte_puts_at_any_alignment() {
        let mut rng = Rng::new(41);
        for _ in 0..200 {
            let lead = rng.below(23) as u32; // 0..22 bits of misalignment
            let n = rng.below(70) as usize;
            let mut payload = vec![0u8; n];
            rng.fill_bytes(&mut payload);
            let lead_v = if lead == 0 { 0 } else { rng.next_u64() & ((1u64 << lead) - 1) };
            let mut a = BitWriter::new();
            let mut b = BitWriter::new();
            a.put(lead_v, lead);
            b.put(lead_v, lead);
            a.put_bytes(&payload);
            for &byte in &payload {
                b.put(byte as u64, 8);
            }
            assert_eq!(a.bit_len(), b.bit_len(), "lead {lead} n {n}");
            assert_eq!(a.finish(), b.finish(), "lead {lead} n {n}");
        }
    }

    #[test]
    fn read_bytes_matches_per_byte_gets_at_any_alignment() {
        let mut rng = Rng::new(43);
        for _ in 0..200 {
            let lead = rng.below(23) as u32;
            let n = rng.below(70) as usize;
            let mut payload = vec![0u8; n + 8];
            rng.fill_bytes(&mut payload);
            let mut w = BitWriter::new();
            w.put(if lead == 0 { 0 } else { 1 }, lead.min(1));
            if lead > 1 {
                w.put(rng.next_u64() & ((1u64 << (lead - 1)) - 1), lead - 1);
            }
            w.put_bytes(&payload);
            let bytes = w.finish();
            let mut a = BitReader::new(&bytes);
            let mut b = BitReader::new(&bytes);
            a.get(lead).unwrap();
            b.get(lead).unwrap();
            let mut out = vec![0u8; n];
            a.read_bytes(&mut out).unwrap();
            assert_eq!(out, payload[..n], "lead {lead} n {n}");
            for (i, &want) in payload[..n].iter().enumerate() {
                assert_eq!(b.get(8).unwrap() as u8, want, "byte {i}");
            }
            assert_eq!(a.bit_pos(), b.bit_pos());
        }
        // short streams fail cleanly in both paths
        let mut r = BitReader::new(&[1, 2]);
        assert_eq!(r.read_bytes(&mut [0u8; 3]), Err(OutOfBits));
        let mut r = BitReader::new(&[1, 2]);
        r.get(3).unwrap();
        assert_eq!(r.read_bytes(&mut [0u8; 2]), Err(OutOfBits));
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [-5i64, 0, 1, -1, i64::MAX, i64::MIN, 123456, -987654] {
            assert_eq!(zigzag_decode(zigzag_encode(v)), v);
        }
        assert_eq!(zigzag_encode(0), 0);
        assert_eq!(zigzag_encode(-1), 1);
        assert_eq!(zigzag_encode(1), 2);
    }

    #[test]
    fn signed_width_edges() {
        assert_eq!(signed_width(0), 0);
        assert_eq!(signed_width(1), 2); // needs [-2,1]
        assert_eq!(signed_width(-1), 1); // fits [-1,0]
        assert_eq!(signed_width(-2), 2);
        assert_eq!(signed_width(7), 4);
        assert_eq!(signed_width(8), 5);
        assert_eq!(signed_width(-8), 4);
        assert_eq!(signed_width(-9), 5);
        assert_eq!(signed_width(127), 8);
        assert_eq!(signed_width(-128), 8);
        assert_eq!(signed_width(128), 9);
    }

    #[test]
    fn signed_width_is_sufficient_and_tight() {
        let mut rng = Rng::new(17);
        for _ in 0..2000 {
            let v = rng.next_u64() as i64 >> rng.range(0, 60);
            let n = signed_width(v).max(1);
            if n > 63 {
                continue; // put_signed caps at 63-bit fields
            }
            let mut w = BitWriter::new();
            w.put_signed(v, n.min(63));
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            assert_eq!(r.get_signed(n.min(63)).unwrap(), v);
            // tightness: one bit fewer must not fit (except v==0/-1 edge)
            if n >= 2 && v != -(1i64 << (n - 2)) {
                let bias = 1i64 << (n - 2);
                assert!(v < -bias || v >= bias, "width {n} not tight for {v}");
            }
        }
    }

    #[test]
    fn clear_reuses_without_leaking_state() {
        let mut w = BitWriter::new();
        w.put(0x5A5A, 16);
        w.put(1, 3);
        w.clear();
        w.put(0b101, 3);
        let bytes = w.finish();
        assert_eq!(bytes, vec![0b101]);
    }

    #[test]
    fn flush_to_byte_then_bytes_matches_finish() {
        let mut rng = Rng::new(21);
        for _ in 0..50 {
            let fields: Vec<(u64, u32)> = (0..rng.range(1, 40))
                .map(|_| {
                    let n = rng.range(1, 58) as u32;
                    (rng.next_u64() & ((1u64 << n) - 1), n)
                })
                .collect();
            let mut a = BitWriter::new();
            let mut b = BitWriter::new();
            for &(v, n) in &fields {
                a.put(v, n);
                b.put(v, n);
            }
            a.flush_to_byte();
            assert_eq!(a.bytes(), b.finish().as_slice());
        }
    }

    #[test]
    fn append_from_moves_bit_ranges_exactly() {
        // build a source stream of known fields, then splice the middle
        // field into a fresh writer and read it back
        let mut src_w = BitWriter::new();
        src_w.put(0b1101, 4);
        src_w.put(0x2AFE, 15);
        src_w.put(0x1F, 5);
        let src = src_w.finish();
        let mut w = BitWriter::new();
        w.put(0b11, 2); // pre-existing bits shift the splice off-byte
        w.append_from(&src, 4, 15);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(2).unwrap(), 0b11);
        assert_eq!(r.get(15).unwrap(), 0x2AFE);
        // wide ranges survive too (crosses several word gulps)
        let mut rng = Rng::new(9);
        let mut big = vec![0u8; 64];
        rng.fill_bytes(&mut big);
        let mut w = BitWriter::new();
        w.append_from(&big, 3, 64 * 8 - 10);
        let out = w.finish();
        let mut ra = BitReader::new(&big);
        ra.get(3).unwrap();
        let mut rb = BitReader::new(&out);
        for _ in 0..(64 * 8 - 10) / 13 {
            assert_eq!(ra.get(13).unwrap(), rb.get(13).unwrap());
        }
    }

    #[test]
    fn append_from_all_alignments_bitwise_exact() {
        // writer alignment x source alignment x ragged lengths; compare
        // against the naive 1-bit-at-a-time splice
        let mut rng = Rng::new(57);
        let mut src = vec![0u8; 40];
        rng.fill_bytes(&mut src);
        for lead in 0..17u32 {
            for off in 0..16usize {
                let nbits = (rng.below(200) + 1).min((src.len() * 8 - off) as u64);
                let lead_v = if lead == 0 { 0 } else { rng.next_u64() & ((1u64 << lead) - 1) };
                let mut a = BitWriter::new();
                a.put(lead_v, lead);
                a.append_from(&src, off, nbits);
                let mut b = BitWriter::new();
                b.put(lead_v, lead);
                for i in 0..nbits as usize {
                    b.put_bit((src[(off + i) / 8] >> ((off + i) % 8)) & 1 == 1);
                }
                assert_eq!(a.bit_len(), b.bit_len(), "lead {lead} off {off} n {nbits}");
                assert_eq!(a.finish(), b.finish(), "lead {lead} off {off} n {nbits}");
            }
        }
    }

    #[test]
    fn overwrite_bits_preserves_surroundings() {
        let mut rng = Rng::new(33);
        for _ in 0..300 {
            let mut dst = vec![0u8; 24];
            rng.fill_bytes(&mut dst);
            let orig = dst.clone();
            let pos = rng.below(150) as usize;
            let nbits = rng.below((dst.len() * 8 - pos) as u64 + 1) as usize;
            let mut src = vec![0u8; nbits.div_ceil(8) + 1];
            rng.fill_bytes(&mut src);
            overwrite_bits(&mut dst, pos, &src, nbits);
            // window holds src's bits; everything else untouched
            for i in 0..dst.len() * 8 {
                let got = (dst[i / 8] >> (i % 8)) & 1;
                let want = if i >= pos && i < pos + nbits {
                    (src[(i - pos) / 8] >> ((i - pos) % 8)) & 1
                } else {
                    (orig[i / 8] >> (i % 8)) & 1
                };
                assert_eq!(got, want, "bit {i} (pos {pos}, nbits {nbits})");
            }
        }
    }

    #[test]
    fn copy_bits_arbitrary_offsets_preserve_surroundings() {
        let mut rng = Rng::new(35);
        for _ in 0..300 {
            let mut dst = vec![0u8; 32];
            let mut src = vec![0u8; 32];
            rng.fill_bytes(&mut dst);
            rng.fill_bytes(&mut src);
            let orig = dst.clone();
            let dpos = rng.below(120) as usize;
            let spos = rng.below(120) as usize;
            let room = (dst.len() * 8 - dpos).min(src.len() * 8 - spos);
            let nbits = rng.below(room as u64 + 1) as usize;
            copy_bits(&mut dst, dpos, &src, spos, nbits);
            for i in 0..dst.len() * 8 {
                let got = (dst[i / 8] >> (i % 8)) & 1;
                let want = if i >= dpos && i < dpos + nbits {
                    let s = spos + (i - dpos);
                    (src[s / 8] >> (s % 8)) & 1
                } else {
                    (orig[i / 8] >> (i % 8)) & 1
                };
                assert_eq!(got, want, "bit {i} (dpos {dpos}, spos {spos}, nbits {nbits})");
            }
        }
    }

    #[test]
    fn bit_pos_tracks() {
        let mut w = BitWriter::new();
        w.put(1, 5);
        w.put(2, 9);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.bit_pos(), 0);
        r.get(5).unwrap();
        assert_eq!(r.bit_pos(), 5);
        r.get(9).unwrap();
        assert_eq!(r.bit_pos(), 14);
    }
}
