//! Bit-level packed stream I/O — the substrate under every codec in this
//! repo (GBDI, BDI, FPC, Huffman).
//!
//! The stream is **LSB-first within a little-endian u64 accumulator**: the
//! first bit written is the lowest bit of the first byte. Fields up to 57
//! bits are written/read in a single shift-or; wider fields are split. This
//! layout lets the hot decoder refill with one unaligned 8-byte load.

/// Append-only bit writer over a growable byte buffer.
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bit accumulator; low `fill` bits are valid and not yet flushed.
    acc: u64,
    fill: u32,
}

impl BitWriter {
    /// New empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// New writer with reserved capacity (bytes).
    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bytes), acc: 0, fill: 0 }
    }

    /// Number of bits written so far.
    #[inline]
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.fill as usize
    }

    /// Write the low `n` bits of `v` (0 <= n <= 64). Bits above `n` in `v`
    /// must be zero (debug-asserted) — callers mask.
    #[inline]
    pub fn put(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        debug_assert!(n == 64 || v < (1u64 << n), "value {v} does not fit {n} bits");
        if n == 0 {
            return;
        }
        if n <= 57 || self.fill + n <= 64 {
            self.acc |= v << self.fill;
            self.fill += n;
            while self.fill >= 8 {
                self.buf.push(self.acc as u8);
                self.acc >>= 8;
                self.fill -= 8;
            }
        } else {
            // Split wide writes.
            let lo_n = 32;
            self.put(v & 0xFFFF_FFFF, lo_n);
            self.put(v >> lo_n, n - lo_n);
        }
    }

    /// Write a single bit.
    #[inline]
    pub fn put_bit(&mut self, b: bool) {
        self.put(b as u64, 1);
    }

    /// Write `n` bits of a signed value in offset-binary (excess-2^(n-1)):
    /// representable range is `[-2^(n-1), 2^(n-1) - 1]`.
    #[inline]
    pub fn put_signed(&mut self, v: i64, n: u32) {
        debug_assert!(n >= 1 && n <= 63);
        let bias = 1i64 << (n - 1);
        debug_assert!(v >= -bias && v < bias, "signed {v} does not fit {n} bits");
        self.put((v + bias) as u64, n);
    }

    /// Finish the stream, zero-padding to a byte boundary, and return the
    /// packed bytes.
    pub fn finish(mut self) -> Vec<u8> {
        self.flush_to_byte();
        self.buf
    }

    /// Reset to an empty stream, keeping the buffer's capacity — the
    /// reuse hook [`crate::codec::Scratch`] is built on (per-block
    /// encodes in a loop must not re-allocate).
    pub fn clear(&mut self) {
        self.buf.clear();
        self.acc = 0;
        self.fill = 0;
    }

    /// Zero-pad to a byte boundary in place (non-consuming [`Self::finish`]):
    /// after this call [`Self::bytes`] exposes the complete packed stream
    /// and further `put`s continue byte-aligned.
    pub fn flush_to_byte(&mut self) {
        while self.fill > 0 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.fill = self.fill.saturating_sub(8);
        }
        self.acc = 0;
    }

    /// The packed bytes written so far. Only whole bytes are visible —
    /// call [`Self::flush_to_byte`] first if the stream may end mid-byte.
    pub fn bytes(&self) -> &[u8] {
        debug_assert_eq!(self.fill, 0, "unflushed bits; call flush_to_byte first");
        &self.buf
    }

    /// Append `nbits` bits copied from `src` starting at bit offset
    /// `bit_off` (same LSB-first layout). The compaction primitive under
    /// [`crate::frame::Frame::to_container`]: blocks are moved between
    /// streams without re-encoding.
    ///
    /// Panics if `src` holds fewer than `bit_off + nbits` bits.
    pub fn append_from(&mut self, src: &[u8], bit_off: usize, nbits: u64) {
        let mut r = BitReader::new(&src[bit_off / 8..]);
        let sub = (bit_off % 8) as u32;
        if sub != 0 {
            r.get(sub).expect("append_from: offset past source");
        }
        let mut rem = nbits;
        while rem > 0 {
            let n = rem.min(57) as u32;
            let v = r.get(n).expect("append_from: source exhausted");
            self.put(v, n);
            rem -= n as u64;
        }
    }

    /// Current byte length if finished now.
    pub fn byte_len(&self) -> usize {
        (self.bit_len() + 7) / 8
    }
}

/// Overwrite `nbits` bits of `dst` starting at bit `pos` with the first
/// `nbits` bits of `src` (both LSB-first packed). Bits of `dst` outside
/// the window are preserved — this is the read-modify-write splice under
/// [`crate::frame::Frame::write_block`]'s in-place path, where a block's
/// new encoding lands inside its old bit span without disturbing the
/// neighbouring blocks that share its boundary bytes.
pub fn overwrite_bits(dst: &mut [u8], pos: usize, src: &[u8], nbits: usize) {
    debug_assert!(pos + nbits <= dst.len() * 8, "overwrite_bits: window past dst");
    debug_assert!(nbits <= src.len() * 8, "overwrite_bits: src too short");
    let mut done = 0usize;
    while done < nbits {
        let byte = (pos + done) / 8;
        let bit = ((pos + done) % 8) as u32;
        let take = (8 - bit).min((nbits - done) as u32);
        // gather `take` bits from src at bit offset `done` (may straddle
        // a byte boundary)
        let sb = done / 8;
        let so = (done % 8) as u32;
        let mut v = (src[sb] >> so) as u16;
        if so + take > 8 {
            v |= (src[sb + 1] as u16) << (8 - so);
        }
        let keep = ((1u16 << take) - 1) as u8;
        let v = (v as u8) & keep;
        dst[byte] = (dst[byte] & !(keep << bit)) | (v << bit);
        done += take as usize;
    }
}

/// Zig-zag encode a signed integer to an unsigned one (small magnitudes →
/// small codes); inverse of [`zigzag_decode`].
#[inline]
pub fn zigzag_encode(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag_encode`].
#[inline]
pub fn zigzag_decode(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

/// Minimum number of bits needed to store `v` in offset-binary signed form
/// (i.e. smallest n with `-2^(n-1) <= v < 2^(n-1)`); 0 for v == 0.
#[inline]
pub fn signed_width(v: i64) -> u32 {
    if v == 0 {
        0
    } else if v > 0 {
        64 - (v as u64).leading_zeros() + 1
    } else {
        64 - ((-(v + 1)) as u64).leading_zeros() + 1
    }
}

/// Error from [`BitReader`] when the stream runs out.
#[derive(Debug, PartialEq, Eq)]
pub struct OutOfBits;

/// Sequential bit reader over a byte slice (same layout as [`BitWriter`]).
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    data: &'a [u8],
    /// Next unread byte index.
    pos: usize,
    acc: u64,
    fill: u32,
}

impl<'a> BitReader<'a> {
    /// Reader over `data` starting at bit 0.
    pub fn new(data: &'a [u8]) -> Self {
        BitReader { data, pos: 0, acc: 0, fill: 0 }
    }

    /// Bits consumed so far.
    #[inline]
    pub fn bit_pos(&self) -> usize {
        self.pos * 8 - self.fill as usize
    }

    /// Bits remaining.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.data.len() * 8 - self.bit_pos()
    }

    #[inline]
    fn refill(&mut self) {
        // Fast path: bulk 8-byte unaligned load.
        if self.pos + 8 <= self.data.len() {
            let w = u64::from_le_bytes(self.data[self.pos..self.pos + 8].try_into().unwrap());
            let take = ((64 - self.fill) / 8) as usize; // whole bytes that fit
            let new_fill = self.fill + take as u32 * 8;
            let mask = if new_fill >= 64 { u64::MAX } else { (1u64 << new_fill) - 1 };
            self.acc |= w.wrapping_shl(self.fill) & mask;
            self.pos += take;
            self.fill = new_fill;
        } else {
            while self.fill <= 56 && self.pos < self.data.len() {
                self.acc |= (self.data[self.pos] as u64) << self.fill;
                self.pos += 1;
                self.fill += 8;
            }
        }
    }

    /// Read `n` bits (0 <= n <= 64).
    #[inline]
    pub fn get(&mut self, n: u32) -> Result<u64, OutOfBits> {
        debug_assert!(n <= 64);
        if n == 0 {
            return Ok(0);
        }
        if n <= 57 {
            if self.fill < n {
                self.refill();
                if self.fill < n {
                    return Err(OutOfBits);
                }
            }
            let v = self.acc & ((1u64 << n) - 1);
            self.acc >>= n;
            self.fill -= n;
            Ok(v)
        } else {
            let lo = self.get(32)?;
            let hi = self.get(n - 32)?;
            Ok(lo | (hi << 32))
        }
    }

    /// Read one bit.
    #[inline]
    pub fn get_bit(&mut self) -> Result<bool, OutOfBits> {
        Ok(self.get(1)? != 0)
    }

    /// Read an `n`-bit offset-binary signed value (see `put_signed`).
    #[inline]
    pub fn get_signed(&mut self, n: u32) -> Result<i64, OutOfBits> {
        debug_assert!(n >= 1 && n <= 63);
        let bias = 1i64 << (n - 1);
        Ok(self.get(n)? as i64 - bias)
    }

    /// Peek `n` bits (n <= 57) without consuming. Bits past the end read as
    /// zero (for Huffman-style table lookups near stream end).
    #[inline]
    pub fn peek(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        if self.fill < n {
            self.refill();
        }
        self.acc & ((1u64 << n) - 1)
    }

    /// Discard bits up to the next byte boundary (chunk realignment in
    /// parallel-compressed streams). No-op when already aligned.
    #[inline]
    pub fn skip_to_byte(&mut self) -> Result<(), OutOfBits> {
        let rem = (self.bit_pos() % 8) as u32;
        if rem != 0 {
            self.get(8 - rem)?;
        }
        Ok(())
    }

    /// Consume `n` bits previously peeked. `n` must be <= current fill.
    #[inline]
    pub fn consume(&mut self, n: u32) -> Result<(), OutOfBits> {
        if self.fill < n {
            self.refill();
            if self.fill < n {
                return Err(OutOfBits);
            }
        }
        self.acc >>= n;
        self.fill -= n;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_fixed_fields() {
        let mut w = BitWriter::new();
        w.put(0b101, 3);
        w.put(0xFFFF, 16);
        w.put(0, 0);
        w.put(1, 1);
        w.put(0x1234_5678_9ABC, 48);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(3).unwrap(), 0b101);
        assert_eq!(r.get(16).unwrap(), 0xFFFF);
        assert_eq!(r.get(0).unwrap(), 0);
        assert_eq!(r.get(1).unwrap(), 1);
        assert_eq!(r.get(48).unwrap(), 0x1234_5678_9ABC);
    }

    #[test]
    fn roundtrip_64bit_fields() {
        let mut w = BitWriter::new();
        w.put(u64::MAX, 64);
        w.put(0xDEAD_BEEF_CAFE_F00D, 64);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(64).unwrap(), u64::MAX);
        assert_eq!(r.get(64).unwrap(), 0xDEAD_BEEF_CAFE_F00D);
    }

    #[test]
    fn roundtrip_random_mixed_widths() {
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let fields: Vec<(u64, u32)> = (0..rng.range(1, 100))
                .map(|_| {
                    let n = rng.range(1, 65) as u32;
                    let v = if n == 64 { rng.next_u64() } else { rng.next_u64() & ((1u64 << n) - 1) };
                    (v, n)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, n) in &fields {
                w.put(v, n);
            }
            let total_bits: usize = fields.iter().map(|&(_, n)| n as usize).sum();
            assert_eq!(w.bit_len(), total_bits);
            let bytes = w.finish();
            assert_eq!(bytes.len(), (total_bits + 7) / 8);
            let mut r = BitReader::new(&bytes);
            for &(v, n) in &fields {
                assert_eq!(r.get(n).unwrap(), v, "width {n}");
            }
        }
    }

    #[test]
    fn signed_roundtrip() {
        let mut w = BitWriter::new();
        let cases = [(-8i64, 4u32), (7, 4), (0, 1), (-1, 1), (-(1 << 30), 31), ((1 << 30) - 1, 31)];
        for &(v, n) in &cases {
            w.put_signed(v, n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &cases {
            assert_eq!(r.get_signed(n).unwrap(), v);
        }
    }

    #[test]
    fn out_of_bits_detected() {
        let bytes = BitWriter::new().finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(1), Err(OutOfBits));
        let mut w = BitWriter::new();
        w.put(3, 2);
        let bytes = w.finish(); // 1 byte, 6 bits padding
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(8).unwrap(), 3); // padding readable as zeros
        assert_eq!(r.get(1), Err(OutOfBits));
    }

    #[test]
    fn peek_consume_matches_get() {
        let mut rng = Rng::new(3);
        let vals: Vec<u64> = (0..64).map(|_| rng.next_u64() & 0x1FFF).collect();
        let mut w = BitWriter::new();
        for &v in &vals {
            w.put(v, 13);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(r.peek(13), v);
            r.consume(13).unwrap();
        }
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [-5i64, 0, 1, -1, i64::MAX, i64::MIN, 123456, -987654] {
            assert_eq!(zigzag_decode(zigzag_encode(v)), v);
        }
        assert_eq!(zigzag_encode(0), 0);
        assert_eq!(zigzag_encode(-1), 1);
        assert_eq!(zigzag_encode(1), 2);
    }

    #[test]
    fn signed_width_edges() {
        assert_eq!(signed_width(0), 0);
        assert_eq!(signed_width(1), 2); // needs [-2,1]
        assert_eq!(signed_width(-1), 1); // fits [-1,0]
        assert_eq!(signed_width(-2), 2);
        assert_eq!(signed_width(7), 4);
        assert_eq!(signed_width(8), 5);
        assert_eq!(signed_width(-8), 4);
        assert_eq!(signed_width(-9), 5);
        assert_eq!(signed_width(127), 8);
        assert_eq!(signed_width(-128), 8);
        assert_eq!(signed_width(128), 9);
    }

    #[test]
    fn signed_width_is_sufficient_and_tight() {
        let mut rng = Rng::new(17);
        for _ in 0..2000 {
            let v = rng.next_u64() as i64 >> rng.range(0, 60);
            let n = signed_width(v).max(1);
            if n > 63 {
                continue; // put_signed caps at 63-bit fields
            }
            let mut w = BitWriter::new();
            w.put_signed(v, n.min(63));
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            assert_eq!(r.get_signed(n.min(63)).unwrap(), v);
            // tightness: one bit fewer must not fit (except v==0/-1 edge)
            if n >= 2 && v != -(1i64 << (n - 2)) {
                let bias = 1i64 << (n - 2);
                assert!(v < -bias || v >= bias, "width {n} not tight for {v}");
            }
        }
    }

    #[test]
    fn clear_reuses_without_leaking_state() {
        let mut w = BitWriter::new();
        w.put(0x5A5A, 16);
        w.put(1, 3);
        w.clear();
        w.put(0b101, 3);
        let bytes = w.finish();
        assert_eq!(bytes, vec![0b101]);
    }

    #[test]
    fn flush_to_byte_then_bytes_matches_finish() {
        let mut rng = Rng::new(21);
        for _ in 0..50 {
            let fields: Vec<(u64, u32)> = (0..rng.range(1, 40))
                .map(|_| {
                    let n = rng.range(1, 58) as u32;
                    (rng.next_u64() & ((1u64 << n) - 1), n)
                })
                .collect();
            let mut a = BitWriter::new();
            let mut b = BitWriter::new();
            for &(v, n) in &fields {
                a.put(v, n);
                b.put(v, n);
            }
            a.flush_to_byte();
            assert_eq!(a.bytes(), b.finish().as_slice());
        }
    }

    #[test]
    fn append_from_moves_bit_ranges_exactly() {
        // build a source stream of known fields, then splice the middle
        // field into a fresh writer and read it back
        let mut src_w = BitWriter::new();
        src_w.put(0b1101, 4);
        src_w.put(0x2AFE, 15);
        src_w.put(0x1F, 5);
        let src = src_w.finish();
        let mut w = BitWriter::new();
        w.put(0b11, 2); // pre-existing bits shift the splice off-byte
        w.append_from(&src, 4, 15);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(2).unwrap(), 0b11);
        assert_eq!(r.get(15).unwrap(), 0x2AFE);
        // wide ranges survive too (crosses several 57-bit chunks)
        let mut rng = Rng::new(9);
        let mut big = vec![0u8; 64];
        rng.fill_bytes(&mut big);
        let mut w = BitWriter::new();
        w.append_from(&big, 3, 64 * 8 - 10);
        let out = w.finish();
        let mut ra = BitReader::new(&big);
        ra.get(3).unwrap();
        let mut rb = BitReader::new(&out);
        for _ in 0..(64 * 8 - 10) / 13 {
            assert_eq!(ra.get(13).unwrap(), rb.get(13).unwrap());
        }
    }

    #[test]
    fn overwrite_bits_preserves_surroundings() {
        let mut rng = Rng::new(33);
        for _ in 0..300 {
            let mut dst = vec![0u8; 24];
            rng.fill_bytes(&mut dst);
            let orig = dst.clone();
            let pos = rng.below(150) as usize;
            let nbits = rng.below((dst.len() * 8 - pos) as u64 + 1) as usize;
            let mut src = vec![0u8; nbits.div_ceil(8) + 1];
            rng.fill_bytes(&mut src);
            overwrite_bits(&mut dst, pos, &src, nbits);
            // window holds src's bits; everything else untouched
            for i in 0..dst.len() * 8 {
                let got = (dst[i / 8] >> (i % 8)) & 1;
                let want = if i >= pos && i < pos + nbits {
                    (src[(i - pos) / 8] >> ((i - pos) % 8)) & 1
                } else {
                    (orig[i / 8] >> (i % 8)) & 1
                };
                assert_eq!(got, want, "bit {i} (pos {pos}, nbits {nbits})");
            }
        }
    }

    #[test]
    fn bit_pos_tracks() {
        let mut w = BitWriter::new();
        w.put(1, 5);
        w.put(2, 9);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.bit_pos(), 0);
        r.get(5).unwrap();
        assert_eq!(r.bit_pos(), 5);
        r.get(9).unwrap();
        assert_eq!(r.bit_pos(), 14);
    }
}
