//! CRC-32 (IEEE 802.3, reflected, polynomial `0xEDB88320`) — the same
//! checksum `zlib.crc32` computes, so Python mirrors can cross-check
//! every digest. One table, two surfaces: the one-shot [`crc32`] and
//! the streaming [`Crc32`] hasher the integrity plane feeds
//! word-at-a-time without materializing intermediate buffers.

/// The reflected CRC-32 lookup table, built at compile time.
const TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// One-shot CRC-32 of `data` (`zlib.crc32`-compatible).
pub fn crc32(data: &[u8]) -> u32 {
    let mut h = Crc32::new();
    h.update(data);
    h.finish()
}

/// Streaming CRC-32 hasher: feed any number of `update` calls, then
/// [`finish`](Self::finish). Feeding the same bytes in any chunking
/// yields the same digest as the one-shot [`crc32`].
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

impl Crc32 {
    /// Fresh hasher.
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Absorb `data`.
    pub fn update(&mut self, data: &[u8]) {
        let mut c = self.state;
        for &b in data {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// Absorb a little-endian `u64` (the hot call in per-block digests).
    pub fn update_u64(&mut self, v: u64) {
        self.update(&v.to_le_bytes());
    }

    /// Final digest (the hasher can keep absorbing afterwards; `finish`
    /// is a pure read of the running state).
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_ieee_reference_vectors() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn streaming_matches_one_shot_under_any_chunking() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let whole = crc32(&data);
        for chunk in [1usize, 3, 7, 64, 4096] {
            let mut h = Crc32::new();
            for c in data.chunks(chunk) {
                h.update(c);
            }
            assert_eq!(h.finish(), whole, "chunk size {chunk}");
        }
        let mut h = Crc32::new();
        h.update_u64(0x0807_0605_0403_0201);
        assert_eq!(h.finish(), crc32(&[1, 2, 3, 4, 5, 6, 7, 8]));
    }
}
