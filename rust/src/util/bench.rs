//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! [`Bencher::bench`] calibrates an iteration count to a target measurement
//! window, runs warmup + measured batches, and reports mean / p50 / p99 and
//! optional throughput. Benches print criterion-style lines and can emit
//! CSV for the experiment logs plus machine-readable JSON
//! ([`Bencher::write_bench_json`] drops `BENCH_<name>.json` at the repo
//! root — the perf-trajectory files CI uploads as artifacts). Scalar
//! outcomes that are not timings (compression ratios, speedup factors)
//! ride along via [`Bencher::metric`].

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark id, e.g. `compress/gbdi/mcf`.
    pub name: String,
    /// Mean wall time per iteration.
    pub mean: Duration,
    /// Median per-batch time (per iteration).
    pub p50: Duration,
    /// 99th percentile per-batch time (per iteration).
    pub p99: Duration,
    /// Iterations measured in total.
    pub iters: u64,
    /// Optional bytes processed per iteration (enables MB/s reporting).
    pub bytes_per_iter: Option<u64>,
}

impl BenchResult {
    /// Throughput in MiB/s if `bytes_per_iter` was set.
    pub fn mib_per_s(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| {
            let secs = self.mean.as_secs_f64();
            b as f64 / (1024.0 * 1024.0) / secs
        })
    }

    /// One human-readable line.
    pub fn line(&self) -> String {
        let tp = match self.mib_per_s() {
            Some(t) => format!("  {t:>9.1} MiB/s"),
            None => String::new(),
        };
        format!(
            "{:<44} mean {:>12}  p50 {:>12}  p99 {:>12}{}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p99),
            tp
        )
    }

    /// CSV row: name,mean_ns,p50_ns,p99_ns,iters,bytes,mibs.
    pub fn csv(&self) -> String {
        format!(
            "{},{},{},{},{},{},{}",
            self.name,
            self.mean.as_nanos(),
            self.p50.as_nanos(),
            self.p99.as_nanos(),
            self.iters,
            self.bytes_per_iter.unwrap_or(0),
            self.mib_per_s().map(|t| format!("{t:.2}")).unwrap_or_default()
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

/// Harness configuration + result sink.
pub struct Bencher {
    /// Warmup window before measuring.
    pub warmup: Duration,
    /// Target total measurement window.
    pub measure: Duration,
    /// Number of batches the window is split into (for percentiles).
    pub batches: usize,
    results: Vec<BenchResult>,
    metrics: Vec<(String, f64)>,
    tags: Vec<(String, String)>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            batches: 20,
            results: Vec::new(),
            metrics: Vec::new(),
            tags: Vec::new(),
        }
    }
}

impl Bencher {
    /// Harness with default windows; honours `GBDI_BENCH_FAST=1` for CI
    /// (shrinks windows ~10x).
    pub fn new() -> Self {
        let mut b = Bencher::default();
        if std::env::var("GBDI_BENCH_FAST").is_ok_and(|v| v == "1") {
            b.warmup = Duration::from_millis(20);
            b.measure = Duration::from_millis(80);
            b.batches = 8;
        }
        b
    }

    /// Measure `f`, which performs exactly one logical iteration per call.
    /// Returns (and records) the result.
    pub fn bench<R>(&mut self, name: &str, bytes_per_iter: Option<u64>, mut f: impl FnMut() -> R) -> &BenchResult {
        // Warmup + calibration: how many iters fit in one batch window?
        let warm_end = Instant::now() + self.warmup;
        let mut warm_iters: u64 = 0;
        let warm_start = Instant::now();
        while Instant::now() < warm_end {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let batch_window = self.measure.as_secs_f64() / self.batches as f64;
        let iters_per_batch = ((batch_window / per_iter.max(1e-9)) as u64).clamp(1, 1 << 24);

        let mut batch_times: Vec<f64> = Vec::with_capacity(self.batches);
        let mut total_iters = 0u64;
        for _ in 0..self.batches {
            let t0 = Instant::now();
            for _ in 0..iters_per_batch {
                std::hint::black_box(f());
            }
            batch_times.push(t0.elapsed().as_secs_f64() / iters_per_batch as f64);
            total_iters += iters_per_batch;
        }
        batch_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = batch_times.iter().sum::<f64>() / batch_times.len() as f64;
        let p50 = batch_times[batch_times.len() / 2];
        let p99 = batch_times[(batch_times.len() * 99 / 100).min(batch_times.len() - 1)];
        let res = BenchResult {
            name: name.to_string(),
            mean: Duration::from_secs_f64(mean),
            p50: Duration::from_secs_f64(p50),
            p99: Duration::from_secs_f64(p99),
            iters: total_iters,
            bytes_per_iter,
        };
        println!("{}", res.line());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// All recorded results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Record a scalar outcome that is not a timing (a compression ratio,
    /// a speedup factor, a quality-loss percentage). Included in the JSON
    /// emission alongside the timing results.
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    /// All recorded scalar metrics.
    pub fn metrics(&self) -> &[(String, f64)] {
        &self.metrics
    }

    /// Attach a string tag describing the measurement environment (the
    /// ISA that served the run, a workload variant...). Tags land in the
    /// JSON `"tags"` object, where the regression gate uses them to
    /// refuse comparing runs from different environments. Last write per
    /// key wins.
    pub fn tag(&mut self, key: &str, value: &str) {
        if let Some(t) = self.tags.iter_mut().find(|(k, _)| k == key) {
            t.1 = value.to_string();
        } else {
            self.tags.push((key.to_string(), value.to_string()));
        }
    }

    /// All recorded tags.
    pub fn tags(&self) -> &[(String, String)] {
        &self.tags
    }

    /// Write all results as CSV to `path` (with header).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "name,mean_ns,p50_ns,p99_ns,iters,bytes_per_iter,mib_per_s")?;
        for r in &self.results {
            writeln!(f, "{}", r.csv())?;
        }
        Ok(())
    }

    /// Render timing results + scalar metrics as a JSON document
    /// (hand-rolled: serde is unavailable offline).
    pub fn to_json(&self, bench: &str) -> String {
        let mut out = String::with_capacity(256 + self.results.len() * 160);
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(bench)));
        out.push_str("  \"tags\": {");
        for (i, (k, v)) in self.tags.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("\"{}\": \"{}\"", json_escape(k), json_escape(v)));
        }
        out.push_str("},\n");
        out.push_str("  \"results\": [\n");
        for (i, r) in self.results.iter().enumerate() {
            let mib = r
                .mib_per_s()
                .map(|t| format!("{t:.3}"))
                .unwrap_or_else(|| "null".into());
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"mean_ns\": {}, \"p50_ns\": {}, \"p99_ns\": {}, \"iters\": {}, \"bytes_per_iter\": {}, \"mib_per_s\": {}}}{}\n",
                json_escape(&r.name),
                r.mean.as_nanos(),
                r.p50.as_nanos(),
                r.p99.as_nanos(),
                r.iters,
                r.bytes_per_iter.map(|b| b.to_string()).unwrap_or_else(|| "null".into()),
                mib,
                if i + 1 < self.results.len() { "," } else { "" }
            ));
        }
        out.push_str("  ],\n");
        out.push_str("  \"metrics\": [\n");
        for (i, (name, value)) in self.metrics.iter().enumerate() {
            let v = if value.is_finite() { format!("{value}") } else { "null".into() };
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"value\": {}}}{}\n",
                json_escape(name),
                v,
                if i + 1 < self.metrics.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the JSON document to an explicit path.
    pub fn write_json(&self, bench: &str, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json(bench))
    }

    /// Write `BENCH_<name>.json` at the repo root (located by walking up
    /// from the current directory — cargo runs benches from the crate
    /// root, one level below it). Returns the path written.
    pub fn write_bench_json(&self, bench: &str) -> std::io::Result<PathBuf> {
        let path = repo_root().join(format!("BENCH_{bench}.json"));
        self.write_json(bench, &path)?;
        Ok(path)
    }
}

/// Locate the repository root: the nearest ancestor of the current
/// directory holding `ROADMAP.md` or `.git` (the crate lives one level
/// below it). Falls back to the current directory.
fn repo_root() -> PathBuf {
    let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    let mut dir = cwd.clone();
    for _ in 0..5 {
        if dir.join("ROADMAP.md").exists() || dir.join(".git").exists() {
            return dir;
        }
        match dir.parent() {
            Some(p) => dir = p.to_path_buf(),
            None => break,
        }
    }
    cwd
}

/// Minimal JSON string escaping for the hand-rolled emitter.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> Bencher {
        Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            batches: 4,
            ..Bencher::default()
        }
    }

    #[test]
    fn bench_measures_something() {
        let mut b = fast();
        let r = b.bench("noop-ish", Some(1024), || std::hint::black_box(1 + 1));
        assert!(r.iters > 0);
        assert!(r.p99 >= r.p50);
        assert!(r.mib_per_s().unwrap() > 0.0 || r.mean.as_nanos() == 0);
    }

    #[test]
    fn ordering_sane_for_slower_work() {
        // LCG chain: serial dependency LLVM cannot close-form or vectorize
        fn churn(n: u64) -> u64 {
            let mut x = std::hint::black_box(1u64);
            for i in 0..std::hint::black_box(n) {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            x
        }
        let mut b = fast();
        let fast_r = b.bench("fast", None, || churn(10)).mean;
        let slow_r = b.bench("slow", None, || churn(100_000)).mean;
        assert!(slow_r > fast_r, "slow {slow_r:?} <= fast {fast_r:?}");
    }

    #[test]
    fn csv_emission() {
        let mut b = fast();
        b.bench("a/b", Some(4096), || 7u32);
        let tmp = std::env::temp_dir().join("gbdi_bench_test.csv");
        b.write_csv(tmp.to_str().unwrap()).unwrap();
        let body = std::fs::read_to_string(&tmp).unwrap();
        assert!(body.starts_with("name,"));
        assert!(body.contains("a/b,"));
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn json_emission_is_well_formed() {
        let mut b = fast();
        b.bench("a/b", Some(4096), || 7u32);
        b.bench("no-throughput", None, || 1u8);
        b.metric("ratio/mcf/\"lloyd\"", 3.25);
        b.metric("speedup", 8.0);
        b.tag("isa", "scalar");
        b.tag("isa", "avx2"); // last write per key wins
        b.tag("host", "ci");
        let json = b.to_json("unit_test");
        assert!(json.contains("\"bench\": \"unit_test\""));
        assert!(json.contains("\"tags\": {\"isa\": \"avx2\", \"host\": \"ci\"}"), "{json}");
        assert!(json.contains("\"name\": \"a/b\""));
        assert!(json.contains("\"mib_per_s\": null"), "{json}");
        assert!(json.contains("\\\"lloyd\\\""), "quotes escaped: {json}");
        assert!(json.contains("\"value\": 3.25"));
        // crude structural sanity: balanced braces/brackets, one trailing
        // newline, no trailing commas before closers
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert!(!json.contains(",\n  ]"), "{json}");
        let tmp = std::env::temp_dir().join("gbdi_bench_test.json");
        b.write_json("unit_test", &tmp).unwrap();
        assert_eq!(std::fs::read_to_string(&tmp).unwrap(), json);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("x\ny"), "x\\ny");
        assert_eq!(json_escape("\u{01}"), "\\u0001");
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_nanos(10)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(10)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(10)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(10)).contains(" s"));
    }
}
