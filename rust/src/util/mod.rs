//! Infrastructure substrates built from scratch for the offline environment:
//! deterministic PRNG, bit-level I/O, sampling/statistics, a thread pool,
//! a property-testing kit, a micro-benchmark harness, and a counting
//! allocator for allocation-budget tests.

pub mod alloc;
pub mod bench;
pub mod bits;
pub mod crc;
pub mod pool;
pub mod prng;
pub mod stats;
pub mod testkit;
