//! A tiny property-based testing kit (proptest is unavailable offline).
//!
//! [`check`] runs a property over `cases` generated inputs from a
//! deterministic seed; on failure it performs greedy shrinking (via the
//! generator's [`Gen::shrink`]) and panics with the minimal failing input
//! and the seed needed to replay it.

use crate::util::prng::Rng;
use std::fmt::Debug;

/// A value generator with optional shrinking.
pub trait Gen {
    /// The generated type.
    type Item: Clone + Debug;
    /// Produce one random value.
    fn gen(&self, rng: &mut Rng) -> Self::Item;
    /// Candidate smaller versions of `v` (tried in order during shrinking).
    fn shrink(&self, _v: &Self::Item) -> Vec<Self::Item> {
        Vec::new()
    }
}

/// Run `prop` on `cases` inputs drawn from `gen` (seed fixed per call site
/// via `seed`). Panics with a replayable report on the first failure after
/// shrinking.
pub fn check<G: Gen>(seed: u64, cases: usize, gen: &G, prop: impl Fn(&G::Item) -> bool) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.gen(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(gen, input, &prop);
            panic!(
                "property failed (seed={seed}, case={case})\nminimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(gen: &G, mut failing: G::Item, prop: &impl Fn(&G::Item) -> bool) -> G::Item {
    // Greedy descent: keep taking the first shrink candidate that still fails.
    let mut budget = 200;
    'outer: while budget > 0 {
        budget -= 1;
        for candidate in gen.shrink(&failing) {
            if !prop(&candidate) {
                failing = candidate;
                continue 'outer;
            }
        }
        break;
    }
    failing
}

/// Generator: `Vec<u8>` with length in `[0, max_len]`, byte values biased
/// towards compressible structure half the time (runs / small values) so
/// codec properties see both regimes.
pub struct BytesGen {
    /// Maximum length of generated vectors.
    pub max_len: usize,
}

impl Gen for BytesGen {
    type Item = Vec<u8>;

    fn gen(&self, rng: &mut Rng) -> Vec<u8> {
        let len = rng.below(self.max_len as u64 + 1) as usize;
        let mode = rng.below(4);
        let mut v = vec![0u8; len];
        match mode {
            0 => rng.fill_bytes(&mut v), // incompressible
            1 => {
                // runs
                let mut i = 0;
                while i < len {
                    let run = (rng.below(32) + 1) as usize;
                    let b = rng.next_u32() as u8;
                    for j in i..(i + run).min(len) {
                        v[j] = b;
                    }
                    i += run;
                }
            }
            2 => {
                // small values
                for b in v.iter_mut() {
                    *b = rng.below(4) as u8;
                }
            }
            _ => {
                // periodic pattern
                let period = (rng.below(8) + 1) as usize;
                let pat: Vec<u8> = (0..period).map(|_| rng.next_u32() as u8).collect();
                for (i, b) in v.iter_mut().enumerate() {
                    *b = pat[i % period];
                }
            }
        }
        v
    }

    fn shrink(&self, v: &Vec<u8>) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        if v.is_empty() {
            return out;
        }
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
        out.push(v[..v.len() - 1].to_vec());
        // zero out a byte
        if let Some(pos) = v.iter().position(|&b| b != 0) {
            let mut w = v.clone();
            w[pos] = 0;
            out.push(w);
        }
        out
    }
}

/// Generator: `Vec<u32>` word values drawn from a clustered mixture (a few
/// dense centers + uniform noise) — the value population GBDI targets.
pub struct WordsGen {
    /// Maximum number of words.
    pub max_words: usize,
    /// Number of mixture centers.
    pub centers: usize,
}

impl Gen for WordsGen {
    type Item = Vec<u32>;

    fn gen(&self, rng: &mut Rng) -> Vec<u32> {
        let n = rng.below(self.max_words as u64 + 1) as usize;
        let centers: Vec<u32> = (0..self.centers.max(1)).map(|_| rng.next_u32()).collect();
        (0..n)
            .map(|_| {
                if rng.chance(0.85) {
                    let c = centers[rng.below(centers.len() as u64) as usize];
                    let spread = 1i64 << rng.below(16);
                    (c as i64).wrapping_add(rng.range_i64(-spread, spread)) as u32
                } else {
                    rng.next_u32()
                }
            })
            .collect()
    }

    fn shrink(&self, v: &Vec<u32>) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        if v.is_empty() {
            return out;
        }
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
        out.push(v[..v.len() - 1].to_vec());
        out
    }
}

/// Generator: pairs of independently generated values.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Item = (A::Item, B::Item);

    fn gen(&self, rng: &mut Rng) -> Self::Item {
        (self.0.gen(rng), self.1.gen(rng))
    }

    fn shrink(&self, v: &Self::Item) -> Vec<Self::Item> {
        let mut out: Vec<Self::Item> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Generator for a u64 in `[lo, hi)`.
pub struct RangeGen {
    /// Inclusive lower bound.
    pub lo: u64,
    /// Exclusive upper bound.
    pub hi: u64,
}

impl Gen for RangeGen {
    type Item = u64;

    fn gen(&self, rng: &mut Rng) -> u64 {
        rng.range(self.lo, self.hi)
    }

    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (v - self.lo) / 2);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 200, &BytesGen { max_len: 256 }, |v| v.len() <= 256);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_report() {
        check(2, 200, &BytesGen { max_len: 64 }, |v| v.len() < 10);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        let gen = BytesGen { max_len: 512 };
        let result = std::panic::catch_unwind(|| {
            check(3, 100, &gen, |v| v.len() < 40);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // minimal counterexample should be close to the boundary (len 40..80)
        let len = msg.matches(", ").count(); // crude but stable: count elements
        assert!(len <= 90, "shrunk below initial sizes: {msg:.80}");
    }

    #[test]
    fn words_gen_respects_bounds() {
        let gen = WordsGen { max_words: 128, centers: 4 };
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            assert!(gen.gen(&mut rng).len() <= 128);
        }
    }

    #[test]
    fn pair_and_range_gens() {
        let gen = PairGen(RangeGen { lo: 2, hi: 10 }, BytesGen { max_len: 8 });
        let mut rng = Rng::new(9);
        for _ in 0..100 {
            let (a, b) = gen.gen(&mut rng);
            assert!((2..10).contains(&a));
            assert!(b.len() <= 8);
        }
        let shr = gen.shrink(&(9, vec![1, 2, 3, 4]));
        assert!(!shr.is_empty());
    }
}
